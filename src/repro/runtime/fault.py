"""Fault tolerance: restart driver, straggler monitor, failure
detector, and deterministic fault injection.

On thousands of nodes the failure model is "some step eventually
dies"; two contracts matter:

* **Resume equivalence** — checkpoint at step k + deterministic data
  (data/synthetic.py is a pure function of step) ⇒ a restarted job
  reproduces the exact trajectory it would have taken.
  ``run_with_restarts`` enforces and tests that contract by
  (optionally) injecting failures.
* **Bounded detection** — a consumer rank that stops heartbeating is
  declared dead within ``max_misses`` lease periods, so the elastic
  controller (``runtime/elastic.py``) can rescale the mesh instead of
  hanging a collective on a ghost. ``FailureDetector`` implements the
  lease protocol; ``docs/elastic.md`` documents it.

``StragglerMonitor`` is the per-process stand-in for fleet-level
straggler mitigation: a robust step-time estimate (EMA + deviation)
flags steps beyond k·σ, and per-rank observations feed a percentile
report the ``FailureDetector`` consumes to evict persistently slow
ranks. ``reset()`` must be called on restart or rescale — the old EMA
describes a trajectory that no longer exists, and the first
post-restore step (restore + recompile) would otherwise be judged
against stale state.

Chaos testing drives everything through ``FaultSchedule``: a pure
function of (step, rank) → active faults, identical on every process,
so multi-process rescale scenarios replay deterministically.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.ckpt import checkpoint as ckpt

# Injected-fault modes (FaultSchedule / InjectedFailure.mode):
KILL_AT_STEP = "kill"             # raise InjectedFailure at the step
HEARTBEAT_DROP = "heartbeat-drop"  # rank silently stops heartbeating
SLOW_RANK = "slow-rank"           # rank's step times inflate
FAULT_MODES = (KILL_AT_STEP, HEARTBEAT_DROP, SLOW_RANK)


@dataclass
class StragglerMonitor:
    alpha: float = 0.1
    threshold: float = 3.0
    ema: Optional[float] = None
    dev: float = 0.0
    slow_steps: List[Dict[str, float]] = field(default_factory=list)
    window: int = 256
    resets: int = 0
    rank_times: Dict[int, List[float]] = field(default_factory=dict)

    def observe(self, step: int, seconds: float,
                rank: Optional[int] = None) -> bool:
        if rank is not None:
            times = self.rank_times.setdefault(int(rank), [])
            times.append(float(seconds))
            del times[:-self.window]
        if self.ema is None:
            self.ema = seconds
            return False
        is_slow = seconds > self.ema + self.threshold * max(self.dev,
                                                            0.05 * self.ema)
        if is_slow:
            self.slow_steps.append({"step": step, "seconds": seconds,
                                    "expected": self.ema})
        self.dev = (1 - self.alpha) * self.dev \
            + self.alpha * abs(seconds - self.ema)
        self.ema = (1 - self.alpha) * self.ema + self.alpha * seconds
        return is_slow

    def reset(self) -> None:
        """Forget the trajectory estimate. Call on restart or rescale:
        the next ``observe`` re-seeds the EMA instead of judging the
        (always slow) restore/recompile step against pre-failure
        state. The slow-step log survives — it is history, not
        estimate."""
        self.ema = None
        self.dev = 0.0
        self.rank_times.clear()
        self.resets += 1

    def rank_report(self, *, percentile: float = 90.0,
                    slow_factor: float = 2.0) -> Dict[str, Any]:
        """Percentile-based per-rank view: a rank whose p-``percentile``
        step time exceeds ``slow_factor`` × the median rank's is slow.
        ``FailureDetector.consume_straggler_report`` turns persistent
        membership in ``slow_ranks`` into eviction."""
        import numpy as np

        per_rank = {r: float(np.percentile(t, percentile))
                    for r, t in sorted(self.rank_times.items()) if t}
        if not per_rank:
            return {"percentile": percentile, "ranks": {},
                    "baseline_s": None, "slow_ranks": []}
        baseline = float(np.median(list(per_rank.values())))
        slow = [r for r, v in per_rank.items()
                if baseline > 0 and v > slow_factor * baseline]
        return {"percentile": percentile, "ranks": per_rank,
                "baseline_s": baseline, "slow_ranks": slow}

    def report(self) -> Dict[str, Any]:
        return {"mean_step_s": self.ema, "dev_s": self.dev,
                "slow_steps": self.slow_steps, "resets": self.resets}


class InjectedFailure(RuntimeError):
    """A deterministically injected fault. ``mode`` is one of
    ``FAULT_MODES``; ``step``/``rank`` locate the injection so chaos
    tests can assert exactly which scheduled fault fired."""

    def __init__(self, message: str = "injected failure", *,
                 mode: str = KILL_AT_STEP,
                 step: Optional[int] = None,
                 rank: Optional[int] = None):
        super().__init__(message)
        self.mode = mode
        self.step = step
        self.rank = rank


@dataclass(frozen=True)
class InjectedFault:
    """One scheduled fault: ``mode`` becomes active at ``step`` on
    ``rank`` and stays active for ``duration`` steps (``None`` =
    forever). ``slow_factor`` only applies to ``SLOW_RANK``."""
    mode: str
    step: int
    rank: int = 0
    duration: Optional[int] = None
    slow_factor: float = 10.0

    def active(self, step: int) -> bool:
        if step < self.step:
            return False
        return self.duration is None or step < self.step + self.duration


class FaultSchedule:
    """A deterministic chaos schedule: the set of active faults is a
    pure function of (step, rank), with no clocks or randomness, so
    every process of a cluster replays the identical scenario — the
    precondition for asserting rescale behavior across ranks."""

    def __init__(self, faults: Iterable[InjectedFault] = ()):
        self.faults = tuple(faults)
        for f in self.faults:
            if f.mode not in FAULT_MODES:
                raise ValueError(f"fault mode must be one of "
                                 f"{FAULT_MODES}, got {f.mode!r}")

    def active(self, step: int) -> List[InjectedFault]:
        return [f for f in self.faults if f.active(step)]

    def check_kill(self, step: int, rank: int = 0) -> None:
        """Raise for a KILL_AT_STEP fault landing exactly on ``step``
        (kills are edges, not levels — a restart replays the step
        without re-dying)."""
        for f in self.faults:
            if (f.mode == KILL_AT_STEP and f.step == step
                    and f.rank == rank):
                raise InjectedFailure(
                    f"injected kill at step {step} rank {rank}",
                    mode=KILL_AT_STEP, step=step, rank=rank)

    def drops_heartbeat(self, step: int, rank: int) -> bool:
        return any(f.mode == HEARTBEAT_DROP and f.rank == rank
                   and f.active(step) for f in self.faults)

    def slow_factor(self, step: int, rank: int) -> float:
        factor = 1.0
        for f in self.faults:
            if f.mode == SLOW_RANK and f.rank == rank and f.active(step):
                factor = max(factor, f.slow_factor)
        return factor


class FailureDetector:
    """Heartbeat/lease failure detector for consumer ranks.

    Each registered rank holds a lease that its heartbeats renew; a
    rank whose last heartbeat is ``max_misses`` lease periods old is
    declared dead on the next ``poll()``. Deadness is permanent until
    the rank re-``register``\\ s (rejoin), so a late heartbeat from a
    declared-dead rank is ignored — the controller may already have
    rebuilt the mesh without it.

    ``clock`` is injectable: wall-seconds in production
    (``time.monotonic``), a fake clock in unit tests, or a *step
    counter* in multi-process demos — steps advance identically on
    every rank, making detection deterministic cluster-wide where
    wall clocks would race.
    """

    def __init__(self, *, lease: float = 1.0, max_misses: int = 3,
                 clock: Callable[[], float] = time.monotonic):
        if lease <= 0:
            raise ValueError(f"lease must be positive, got {lease}")
        if max_misses < 1:
            raise ValueError(f"max_misses must be >= 1, got {max_misses}")
        self.lease = float(lease)
        self.max_misses = int(max_misses)
        self.clock = clock
        self._last: Dict[int, float] = {}       # rank -> last heartbeat
        self._dead: Dict[int, str] = {}         # rank -> reason
        self._suspect_streak: Dict[int, int] = {}
        self.events: List[Dict[str, Any]] = []

    # -- membership ----------------------------------------------------------
    def register(self, rank: int, now: Optional[float] = None) -> None:
        """Grant (or re-grant, on rejoin) a fresh lease."""
        rank = int(rank)
        self._last[rank] = self.clock() if now is None else now
        self._suspect_streak.pop(rank, None)
        if rank in self._dead:
            del self._dead[rank]
            self.events.append({"event": "rejoin", "rank": rank})

    def deregister(self, rank: int) -> None:
        """Graceful leave: no death event, just gone."""
        self._last.pop(int(rank), None)
        self._suspect_streak.pop(int(rank), None)

    def heartbeat(self, rank: int, now: Optional[float] = None) -> None:
        rank = int(rank)
        if rank in self._dead:
            return                      # lease already revoked; rejoin first
        if rank not in self._last:
            raise KeyError(f"rank {rank} is not registered")
        self._last[rank] = self.clock() if now is None else now

    # -- verdicts ------------------------------------------------------------
    def missed(self, rank: int, now: Optional[float] = None) -> int:
        now = self.clock() if now is None else now
        return int((now - self._last[int(rank)]) / self.lease)

    def poll(self, now: Optional[float] = None) -> Dict[str, Any]:
        """Evaluate every lease. Newly expired ranks transition to
        dead exactly once (an event is recorded); the returned
        ``new_dead`` list is what a controller acts on."""
        now = self.clock() if now is None else now
        new_dead: List[int] = []
        missed: Dict[int, int] = {}
        for rank in sorted(self._last):
            if rank in self._dead:
                continue
            n = self.missed(rank, now)
            missed[rank] = n
            if n >= self.max_misses:
                self._declare(rank, f"missed {n} heartbeats")
                new_dead.append(rank)
        return {"now": now, "new_dead": new_dead,
                "dead": self.dead_ranks(),
                "alive": self.alive_ranks(), "missed": missed}

    def declare_dead(self, rank: int, reason: str = "operator") -> None:
        """Out-of-band verdict (operator action, or a peer's agreed
        verdict broadcast by the elastic controller)."""
        rank = int(rank)
        if rank not in self._dead:
            self._declare(rank, reason)

    def _declare(self, rank: int, reason: str) -> None:
        self._dead[rank] = reason
        self._suspect_streak.pop(rank, None)
        self.events.append({"event": "dead", "rank": rank,
                            "reason": reason})

    def consume_straggler_report(self, report: Dict[str, Any], *,
                                 evict_after: int = 3) -> List[int]:
        """Feed a ``StragglerMonitor.rank_report``: a rank slow in
        ``evict_after`` *consecutive* reports is evicted (declared
        dead) — one slow percentile is noise, a persistent one is a
        failing node. Returns the newly evicted ranks."""
        slow = {int(r) for r in report.get("slow_ranks", ())}
        evicted: List[int] = []
        for rank in list(self._last):
            if rank in self._dead:
                continue
            if rank in slow:
                streak = self._suspect_streak.get(rank, 0) + 1
                self._suspect_streak[rank] = streak
                if streak >= evict_after:
                    self._declare(rank, f"straggler in {streak} "
                                        f"consecutive reports")
                    evicted.append(rank)
            else:
                self._suspect_streak.pop(rank, None)
        return evicted

    # -- introspection -------------------------------------------------------
    def alive_ranks(self) -> List[int]:
        return sorted(r for r in self._last if r not in self._dead)

    def dead_ranks(self) -> List[int]:
        return sorted(self._dead)

    def suspect_ranks(self) -> List[int]:
        return sorted(r for r, n in self._suspect_streak.items() if n > 0)

    def report(self) -> Dict[str, Any]:
        return {"lease": self.lease, "max_misses": self.max_misses,
                "alive": self.alive_ranks(), "dead": dict(self._dead),
                "suspect": self.suspect_ranks(),
                "events": list(self.events)}


def run_with_restarts(*, make_state: Callable[[], Any],
                      train_step: Callable[[Any, Any], Any],
                      batch_fn: Callable[[int], Any],
                      total_steps: int,
                      ckpt_dir, ckpt_every: int = 10,
                      state_shardings=None,
                      fail_at: Optional[List[int]] = None,
                      max_restarts: int = 10,
                      on_metrics: Optional[Callable] = None):
    """Training driver with checkpoint/restart semantics.

    ``fail_at``: steps at which to inject a failure (testing). Each
    failure triggers restore-from-latest and replay, exactly as a real
    preemption/node-loss restart would.
    """
    fail_at = set(fail_at or [])
    restarts = 0
    monitor = StragglerMonitor()

    state = None
    while True:
        try:
            start = ckpt.latest_step(ckpt_dir)
            if state is None:
                state = make_state()
                if start is not None:
                    state = ckpt.restore(ckpt_dir, start, state,
                                         shardings=state_shardings)
            step = start if start is not None else 0
            while step < total_steps:
                if step in fail_at:
                    fail_at.discard(step)
                    state = None               # simulate losing the node
                    raise InjectedFailure(f"injected at step {step}",
                                          mode=KILL_AT_STEP, step=step)
                t0 = time.perf_counter()
                state, metrics = train_step(state, batch_fn(step))
                monitor.observe(step, time.perf_counter() - t0)
                step += 1
                if on_metrics is not None:
                    on_metrics(step, metrics)
                if step % ckpt_every == 0 or step == total_steps:
                    ckpt.save(ckpt_dir, step, state)
            return state, {"restarts": restarts,
                           "straggler": monitor.report()}
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            # the pre-failure EMA would judge the restore+recompile
            # step against a trajectory that no longer exists
            monitor.reset()
