"""Pallas TPU kernel: fused flash attention (forward).

The §Roofline tables repeatedly flag unfused attention as the memory
bottleneck: at HLO level every (q-block × kv-block) logits tile round-
trips HBM. This kernel keeps the running-softmax state (m, l, acc) in
VMEM for a whole q block while streaming K/V blocks, so the S×S logits
never touch HBM — the classic flash schedule, MXU-shaped (q·kᵀ and p·v
as 128-aligned matmuls).

Layout: grid (B·H, S/block_q). Per program: q block (block_q, hd) and
the full per-head K/V (S, hd) resident in VMEM (fine through S≈8k at
hd=128; longer sequences would add a kv grid axis). GQA is handled in
the BlockSpec index maps: the K/V block index is derived from the query
head, so K/V are NOT repeated in HBM. Causal masking and gemma-style
logit softcap are fused.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
            seq: int, causal: bool, cap: float, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    bq, hd = q.shape
    nk = seq // block_k

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(
            k_ref[0], j * block_k, block_k, axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(
            v_ref[0], j * block_k, block_k, axis=0).astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if cap > 0.0:
            s = jnp.tanh(s / cap) * cap
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1)
            s = jnp.where(qpos >= kpos, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), -1e30, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    if causal:
        # only blocks j with j*block_k <= (qi+1)*block_q - 1 contribute
        nk_needed = (qi * block_q + block_q + block_k - 1) // block_k
        nk_run = jnp.minimum(nk_needed, nk)
    else:
        nk_run = nk
    m, l, acc = jax.lax.fori_loop(0, nk_run, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, softcap: float = 0.0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q (B, S, H, hd) · k/v (B, S, KV, hd), H = G·KV → out (B, S, H, hd).

    Causal flash attention with fused optional logit softcap. K/V heads
    are shared across query-head groups via index maps (no repeat)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0

    # (B,S,H,hd) -> (B*H, S, hd) program-major layout
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    def kv_index(bh, qi):
        return (bh // H) * KV + (bh % H) // G

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, seq=S,
                          causal=causal, cap=float(softcap),
                          scale=1.0 / math.sqrt(hd)),
        grid=(B * H, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, S, hd), lambda bh, qi: (kv_index(bh, qi), 0, 0)),
            pl.BlockSpec((1, S, hd), lambda bh, qi: (kv_index(bh, qi), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
