"""2-D incompressible Navier–Stokes, vorticity form, pseudo-spectral.

    ∂ω/∂t + u·∇ω = ν∇²ω,   u = (∂ψ/∂y, −∂ψ/∂x),   ∇²ψ = −ω

on the periodic box [0,2π)², after spectralDNS' ``NS2D`` solver but
driven entirely through the distributed plan cache: every velocity /
gradient inverse transform and the forward transform of the advection
product go through the SAME two cached plans (``plan_rfft`` fwd/bwd —
or ``plan_dft`` with ``real=False``), so a solver step is the
repeated-transform, c2r-dominated workload of the paper's in-situ
chain.  The nonlinear term is 2/3-rule dealiased through the basis'
layout-matched mask; per-RHS cost is ONE batched 4-field inverse (u, v,
∂ₓω, ∂ᵧω stacked on a ``batch_ndim=1`` plan) + one forward transform.

Taylor–Green, ``ω = 2 sin x sin y``, is an exact solution whose
Jacobian vanishes identically, giving closed-form decay
``ω(t) = ω₀·e^{−2νt}`` — the analytic oracle in ``tests/test_solver.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solver.base import SpectralSolverBase
from repro.core.solver.spectral import SpectralBasis


class NS2DSolver(SpectralSolverBase):
    """State: one (re, im) pair holding the vorticity spectrum ω̂."""

    def __init__(self, shape: Tuple[int, int], mesh, *, nu: float = 1e-3,
                 dt: float = 1e-2, decomp: Optional[str] = None,
                 axis_names=None, real: bool = True, backend: str = "auto",
                 wire_dtype=None, stepper: str = "if_rk4"):
        assert len(shape) == 2, "NS2DSolver wants a 2-D grid"
        basis = SpectralBasis(shape, mesh, decomp=decomp,
                              axis_names=axis_names, real=real,
                              backend=backend, wire_dtype=wire_dtype)
        super().__init__(basis, dt=dt, stepper=stepper)
        self.nu = float(nu)
        b = basis
        k0, k1 = b.k
        decay = -self.nu * b.k2_np      # host numpy; placed in finalize
        self._decay_tree = (decay, decay)
        self._finalize_setup()
        # dealias + zero the k=0 bin: the Jacobian is a divergence, so
        # its mean is zero analytically — pinning it keeps mean(ω)
        # exactly conserved instead of drifting at round-off
        nlmask = b.dealias * jnp.asarray(np.asarray(b.k2) > 0, jnp.float32)

        @jax.jit
        def spectral_ops(re, im):
            """ω̂ → stacked (û, v̂, ∂xω̂, ∂yω̂) batch: ψ̂ = ω̂/k²,
            û = ik₁ψ̂, v̂ = −ik₀ψ̂; i·(re,im)·k = (−k·im, k·re). One
            (4, …) stack → ONE batched c2r execute (see
            ``SpectralBasis.bwd_batch``)."""
            pre, pim = re * b.inv_k2, im * b.inv_k2
            res = jnp.stack((-k1 * pim, k0 * pim, -k0 * im, -k1 * im))
            ims = jnp.stack((k1 * pre, -k0 * pre, k0 * re, k1 * re))
            return res, ims

        @jax.jit
        def advect(w):
            u, v, wx, wy = w
            return -(u * wx + v * wy)

        @jax.jit
        def dealias(re, im):
            return re * nlmask, im * nlmask

        self._spectral_ops = spectral_ops
        self._advect = advect
        self._dealias = dealias

    # -- RHS -----------------------------------------------------------------
    def _nonlinear(self, state):
        b = self.basis
        w = b.to_real_batch(*self._spectral_ops(*state))
        return self._dealias(*b.forward(self._advect(w)))

    # -- initialization ------------------------------------------------------
    def init_vorticity(self, w0: np.ndarray) -> None:
        """Set the state from a natural-layout real vorticity field
        (dealiased on entry so step 0 already lives in the resolved
        band)."""
        self.state = self._dealias(*self.basis.to_spectral(w0))
        self.t = 0.0
        self.step_count = 0

    def init_taylor_green(self, amplitude: float = 1.0) -> None:
        """ω₀ = 2A·sin x·sin y (the ψ = A·sin x·sin y vortex array)."""
        n0, n1 = self.basis.shape
        x = 2.0 * np.pi * np.arange(n0) / n0
        y = 2.0 * np.pi * np.arange(n1) / n1
        self.init_vorticity(2.0 * amplitude
                            * np.outer(np.sin(x), np.sin(y)))

    def init_random(self, seed: int = 0, kpeak: int = 4,
                    amplitude: float = 1.0) -> None:
        """Smooth random field: white noise low-passed to |k| ≤ kpeak
        per axis (deterministic in ``seed``; built in numpy so every
        schedule sees the identical initial condition)."""
        n0, n1 = self.basis.shape
        rng = np.random.default_rng(seed)
        spec = np.fft.rfft2(rng.standard_normal((n0, n1)))
        kx = np.minimum(np.arange(n0), n0 - np.arange(n0))
        ky = np.arange(spec.shape[1])
        keep = (kx[:, None] <= kpeak) & (ky[None, :] <= kpeak)
        keep[0, 0] = False
        w = np.fft.irfft2(spec * keep, s=(n0, n1))
        self.init_vorticity(amplitude * w / max(np.abs(w).max(), 1e-12))

    # -- diagnostics ---------------------------------------------------------
    def vorticity(self) -> np.ndarray:
        """Natural-layout real ω."""
        return self.basis.gather_real(self.basis.to_real(*self.state))

    def energy(self) -> float:
        """Kinetic energy ½⟨|u|²⟩ = ½·Σ w·|ω̂|²/k² /N²."""
        return self._weighted_sum(self.state, extra=self.basis.inv_k2)

    def enstrophy(self) -> float:
        """½⟨ω²⟩."""
        return self._weighted_sum(self.state)

    def spectrum(self, nbins: int = 32, kind: str = "energy"):
        """Shell-summed E(k) (``kind="energy"``) or Z(k)
        (``kind="enstrophy"``)."""
        extra = self.basis.inv_k2 if kind == "energy" else None
        return self.spectrum_pair(self.state, nbins, extra=extra)
