"""Multi-process cluster bootstrap — ``jax.distributed`` made boring.

Everything in this repo below the launch layer is already written
against *global* meshes and collectives; the only thing standing
between the single-host reproduction and the paper's actual deployment
shape (an FFT running across the machines producing the data) is
process bring-up. This module owns exactly that:

* **Discovery** — ``ClusterConfig.from_env()`` reads the
  ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``
  environment contract that ``tools/launch_multihost.py`` exports, and
  ``add_cluster_args``/``config_from_args`` expose the same knobs as
  CLI flags for schedulers that prefer argv over env.
* **Initialization** — ``init_cluster()`` is idempotent, a no-op for
  single-process runs, and routes every drifting JAX API through
  ``repro.compat`` (gloo CPU collectives, ``distributed.initialize``
  signature drift). It must run BEFORE the first JAX backend use; on
  CPU the per-process device count additionally needs
  ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` set before
  the first ``import jax`` (the launcher does both).
* **Topology queries** — ``axis_crosses_processes(mesh, axis)`` is the
  primitive behind the schedule engine's host-crossing ``AllToAll``
  annotation (see ``core/fft/schedule.py``): an exchange over a mesh
  axis whose device ring spans more than one process pays DCN latency,
  not ICI, which is exactly the regime where the slab/pencil tradeoff
  inverts (Verma et al., arXiv:2202.12756).

Deployment guide with the full bootstrap walkthrough:
``docs/multihost.md``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

import jax

from repro import compat

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

_STATE: Dict[str, object] = {"initialized": False, "config": None}


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One process's view of the cluster.

    ``coordinator`` is ``host:port`` of process 0's coordination
    service (every process passes the SAME address, including process
    0 itself); ``num_processes``/``process_id`` complete the contract.
    The default instance describes a single-process run, for which
    ``init_cluster`` does nothing — launch code can call it
    unconditionally.
    """
    coordinator: Optional[str] = None
    num_processes: int = 1
    process_id: int = 0

    @property
    def multiprocess(self) -> bool:
        return self.num_processes > 1

    @classmethod
    def from_env(cls, env: Optional[Dict[str, str]] = None
                 ) -> "ClusterConfig":
        """Read the ``REPRO_*`` environment contract (the launcher's
        export format). Unset variables yield the single-process
        default; a coordinator with no process count is an error (a
        half-configured cluster should fail loudly at bring-up, not
        hang at the first collective)."""
        e = os.environ if env is None else env
        coord = e.get(ENV_COORDINATOR) or None
        nprocs = int(e.get(ENV_NUM_PROCESSES, "1"))
        pid = int(e.get(ENV_PROCESS_ID, "0"))
        if coord is not None and ENV_NUM_PROCESSES not in e:
            raise ValueError(
                f"{ENV_COORDINATOR} is set but {ENV_NUM_PROCESSES} is "
                f"not — export both (and {ENV_PROCESS_ID} per process)")
        if nprocs > 1 and ENV_PROCESS_ID not in e:
            # without an explicit rank every process defaults to 0 and
            # bring-up deadlocks waiting for the other ranks
            raise ValueError(
                f"{ENV_NUM_PROCESSES}={nprocs} but {ENV_PROCESS_ID} is "
                f"not set — export a distinct rank (0..{nprocs - 1}) "
                f"per process")
        return cls(coordinator=coord, num_processes=nprocs, process_id=pid)


def add_cluster_args(parser) -> None:
    """Attach the flag-driven discovery knobs to an argparse parser
    (the env contract's CLI twin; flags win over env when both set)."""
    parser.add_argument("--coordinator", default=None,
                        help="host:port of process 0's coordination "
                             "service (default: $REPRO_COORDINATOR)")
    parser.add_argument("--num-processes", type=int, default=None,
                        help="total processes in the cluster "
                             "(default: $REPRO_NUM_PROCESSES)")
    parser.add_argument("--process-id", type=int, default=None,
                        help="this process's rank "
                             "(default: $REPRO_PROCESS_ID)")


def config_from_args(args, env: Optional[Dict[str, str]] = None
                     ) -> ClusterConfig:
    """Merge ``add_cluster_args`` flags over the env contract."""
    cfg = ClusterConfig.from_env(env)
    coord = getattr(args, "coordinator", None)
    nprocs = getattr(args, "num_processes", None)
    pid = getattr(args, "process_id", None)
    return ClusterConfig(
        coordinator=coord if coord is not None else cfg.coordinator,
        num_processes=nprocs if nprocs is not None else cfg.num_processes,
        process_id=pid if pid is not None else cfg.process_id)


def init_cluster(config: Optional[ClusterConfig] = None) -> ClusterConfig:
    """Initialize ``jax.distributed`` from ``config`` (default:
    ``ClusterConfig.from_env()``). Idempotent: the first call wins and
    later calls return its config (re-initializing a live distributed
    runtime is not supported by JAX). Single-process configs skip
    backend initialization entirely, so every entry point can call this
    unconditionally at startup."""
    if _STATE["initialized"]:
        return _STATE["config"]          # type: ignore[return-value]
    cfg = ClusterConfig.from_env() if config is None else config
    if cfg.multiprocess:
        if cfg.coordinator is None:
            raise ValueError(
                "multi-process ClusterConfig needs a coordinator "
                "address (host:port of process 0)")
        # must precede backend init or CPU collectives stay unimplemented
        compat.enable_cpu_collectives()
        compat.distributed_initialize(cfg.coordinator, cfg.num_processes,
                                      cfg.process_id)
    _STATE["initialized"] = True
    _STATE["config"] = cfg
    return cfg


def is_initialized() -> bool:
    return bool(_STATE["initialized"])


def shutdown_cluster() -> None:
    """Tear down the distributed runtime (tests/launcher epilogue);
    safe to call when never initialized."""
    cfg = _STATE["config"]
    if cfg is not None and cfg.multiprocess:  # type: ignore[union-attr]
        compat.distributed_shutdown()
    _STATE["initialized"] = False
    _STATE["config"] = None


def cluster_info() -> Dict[str, object]:
    """This process's runtime view — what ``docs/multihost.md`` tells
    operators to log first when a bring-up misbehaves."""
    return {
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": len(jax.local_devices()),
        "global_devices": len(jax.devices()),
        "platform": jax.devices()[0].platform,
        "initialized": is_initialized(),
    }


# ---------------------------------------------------------------------------
# Mesh topology queries — which axes cross hosts
# ---------------------------------------------------------------------------
# The primitives live in repro.compat (below every layer, so the core
# FFT schedule engine can use them without importing runtime); this is
# their documented runtime-facing home.
axis_crosses_processes = compat.axis_crosses_processes
mesh_process_topology = compat.mesh_process_topology
