"""Decoder-only LM (plus the VLM variant) — init / train / prefill / decode.

Entry points consumed by launch/dryrun.py, the training driver and the
serving engine:

* ``init_params(cfg, key, dtype)``
* ``loss_fn(cfg, params, batch, policy)``             — train objective
* ``prefill(cfg, params, batch, policy, cache_len)``  — logits + caches
* ``decode_step(cfg, params, token, state, policy)``  — one-token serve
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.common import chunked_softmax_xent, embed_init, rms_norm, softcap
from repro.serve.kvcache import from_prefill, init_cache

VIT_STUB_DIM = 4096  # InternVL2: pixel-shuffled InternViT feature width


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    G = blk.n_groups(cfg)
    gkeys = jax.random.split(ks[0], G)
    blocks = jax.vmap(
        lambda k: blk.init_period_params(cfg, k, dtype))(gkeys)
    params: Dict[str, Any] = {
        "embedding": embed_init(ks[1], (cfg.vocab_size, cfg.d_model), dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[2], (cfg.d_model, cfg.vocab_size),
                                    dtype)
    shared = blk.init_shared_params(cfg, ks[3], dtype)
    if shared is not None:
        params["shared"] = shared
    if cfg.frontend == "vit_stub":
        params["patch_proj"] = embed_init(
            ks[4], (VIT_STUB_DIM, cfg.d_model), dtype)
    return params


def head_weights(cfg, params):
    if cfg.tie_embeddings:
        return params["embedding"].T
    return params["head"]


# ---------------------------------------------------------------------------
# Embedding / input assembly
# ---------------------------------------------------------------------------

def embed_inputs(cfg, params, batch, policy=None):
    """tokens (B,S) [+ patch_embeds (B,P,VIT)] -> hidden (B,S,D).

    For the VLM, the first ``num_patches`` positions of the sequence are
    image positions: projected patch embeddings replace the token
    embeddings there (frontend is a stub per the assignment)."""
    x = jnp.take(params["embedding"], batch["tokens"], axis=0)
    if cfg.frontend == "vit_stub" and "patch_embeds" in batch:
        patches = jnp.einsum("bpk,kd->bpd", batch["patch_embeds"],
                             params["patch_proj"]).astype(x.dtype)
        x = jnp.concatenate([patches, x[:, patches.shape[1]:]], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if policy is not None:
        x = policy.constrain(x, policy.act_hidden())
    return x


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, batch, policy=None, *, remat: bool = True,
            remat_policy=None, loss_chunk: int = 512,
            aux_weight: float = 0.01):
    """Causal-LM loss. batch: tokens (B,S), labels (B,S) (−1 = pad)."""
    x = embed_inputs(cfg, params, batch, policy)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux = blk.stack_forward(cfg, params["blocks"], x, positions, policy,
                               params.get("shared"), remat=remat,
                               remat_policy=remat_policy)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    constrain = ((lambda t: policy.constrain(t, policy.act_logits(cfg.vocab_size)))
                 if policy is not None else None)
    loss_sum, count = chunked_softmax_xent(
        x, head_weights(cfg, params), batch["labels"], chunk=loss_chunk,
        constrain=constrain, final_cap=cfg.final_softcap)
    loss = loss_sum / jnp.maximum(count, 1.0)
    metrics = {"loss": loss, "tokens": count, "aux_loss": aux}
    if cfg.moe is not None:
        loss = loss + aux_weight * aux
    return loss, metrics


# ---------------------------------------------------------------------------
# Serve: prefill + decode
# ---------------------------------------------------------------------------

def _logits_last(cfg, params, x, policy):
    """Final-position logits only (B,1,V)."""
    h = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps,
                 plus_one=True)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        head_weights(cfg, params).astype(jnp.float32))
    logits = softcap(logits, cfg.final_softcap)
    if policy is not None:
        logits = policy.constrain(logits, policy.act_logits(cfg.vocab_size))
    return logits


def prefill(cfg, params, batch, policy=None, *, cache_len: int = 0):
    """Run the full prompt; return (last-position logits, decode state).

    decode state = (caches pytree stacked over depth, ssm states, ssm
    positions); caches are rolled/padded to ``cache_len`` slots."""
    x = embed_inputs(cfg, params, batch, policy)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, raw_caches, states = blk.stack_prefill(
        cfg, params["blocks"], x, positions, policy, params.get("shared"))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)

    caches = {}
    for i, kind in enumerate(cfg.layer_pattern):
        key = f"l{i}"
        if key not in raw_caches:
            continue
        k, v = raw_caches[key]

        def mk(kv_pair, window):
            kk, vv = kv_pair
            return jax.vmap(
                lambda a, b: from_prefill(a, b, window=window,
                                          pad_to=cache_len))(kk, vv)
        window = cfg.window if kind == "swa" and cfg.window else 0
        caches[key] = mk((k, v), window)
    logits = _logits_last(cfg, params, x, policy)
    return logits, {"caches": caches, "ssm": states, "pos": S}


def init_decode_state(cfg, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, policy=None, *,
                      cache_impl: str = "dense"):
    """Fresh (empty) decode state for decode-only dry-run cells.

    ``cache_impl``: "dense" (dtype K/V) or "int8" (quantized storage,
    §Perf lever — halves the cache's HBM footprint/traffic)."""
    from repro.models.ssm import init_ssm_state
    from repro.serve.kvcache import init_quant_cache

    def mk_cache(window=0):
        if cache_impl == "int8":
            return init_quant_cache(batch, cache_len, cfg.num_kv_heads,
                                    cfg.head_dim, window=window)
        return init_cache(batch, cache_len, cfg.num_kv_heads, cfg.head_dim,
                          dtype, window=window)

    G = blk.n_groups(cfg)
    caches, states = {}, {}
    for i, kind in enumerate(cfg.layer_pattern):
        key = f"l{i}"
        if kind in ("full",):
            c = mk_cache()
        elif kind == "swa":
            c = mk_cache(window=cfg.window or cache_len)
        elif kind == "hybrid":
            c = mk_cache()
            states[key] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (G,) + x.shape),
                init_ssm_state(cfg, batch, dtype))
        else:  # ssm
            states[key] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (G,) + x.shape),
                init_ssm_state(cfg, batch, dtype))
            continue
        caches[key] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (G,) + x.shape), c)
    return {"caches": caches, "ssm": states, "pos": 0}


def decode_step(cfg, params, tokens, state, policy=None):
    """tokens (B,1) int32; state from prefill/init_decode_state.
    Returns (logits (B,1,V), new state)."""
    x = embed_inputs(cfg, params, {"tokens": tokens}, policy)
    cur_pos = state["pos"]
    x, new_caches, new_states = blk.stack_decode(
        cfg, params["blocks"], x, state["caches"], state["ssm"], cur_pos,
        policy, params.get("shared"))
    logits = _logits_last(cfg, params, x, policy)
    return logits, {"caches": new_caches, "ssm": new_states,
                    "pos": cur_pos + 1}
