"""Wire codecs — compressed transport formats for distribution exchanges.

Multi-node distributed FFT is all_to_all-bound (Verma et al.,
arXiv:2202.12756): the bytes an exchange moves over DCN set the
scaling ceiling, not the local FLOPs. ``schedule.py`` already treats
the wire *dtype* as a plan knob (``wire_dtype="bfloat16"`` halves the
collective bytes); this module generalizes that idea to wire
**codecs**: an exchange may encode its payload into a compressed
representation (int8 payload + per-block float scales), move the
compressed parts through the same tiled ``all_to_all``, and decode on
arrival. Compute stays f32 everywhere — only the wire is lossy, and
each codec documents an elementwise error bound that the planner's
error-budget gate (``plan.py``, ``wire_tol``) verifies against the
exact-wire oracle before a codec may win a measured sweep.

Codecs (``get_codec(name)``; names are plain strings so schedules stay
hashable, exactly like ``wire_dtype``):

========== ===================== =========================== =========
name       wire format           elementwise error bound     bytes/elt
========== ===================== =========================== =========
``bf16``   bfloat16 cast         ``2^-8 · |x|``              2
``int8``   int8 + 1 scale/row    ``absmax_row / 254``        1 + 4/n
``int8_blockB`` int8 + 1 scale   ``absmax_block / 254``      1 + 4/B
           per B-elt block
========== ===================== =========================== =========

(``absmax`` is the max magnitude over the scaling span; ``row`` = the
whole last axis. ``int8_block64`` is the stock block-scaled codec; any
``int8_block<B>`` name parses.) The block-scaled variant exists
because a single outlier poisons a global absmax — every other value
collapses toward zero (the historical ``optim/compress.py`` bug, now
fixed by delegating here): per-block scales contain the damage to the
outlier's own block.

**Complex payloads** are handled as interleaved re/im planes: a
complex array is viewed as a real array whose last axis interleaves
``re0, im0, re1, im1, …`` (``interleave_complex``), encoded as usual,
and de-interleaved on decode — so a block's scale always covers
spatially adjacent complex samples. (The schedule executor never needs
this: its state is already split (re, im) f32 pairs.)

**Exchange alignment.** ``AllToAll`` moves the encoded parts as ONE
packed byte buffer through a single tiled all_to_all
(``pack_wire``/``unpack_wire``): each shard's slice of the buffer
holds that shard's payload bytes followed by its scale bytes, so one
collective carries the whole codec wire. One collective is not just
one message of latency — it is a *correctness* requirement on the CPU
gloo transport, where two concurrently-scheduled collectives with
different message sizes on the same mesh axis can cross-pair their
messages and abort (preamble length mismatch). Blocks stay atomic
through the exchange as long as the payload's last-axis extent is a
multiple of the block size on both sides; ``encode_wire`` enforces
exact divisibility and raises ``ValueError`` otherwise — at trace
time, where the planner's sweep records it as an ordinary skipped
candidate (``pack_wire`` enforces the analogous per-shard divisibility
when the exchange splits the last axis). Standalone
``encode``/``decode`` (gradient compression, tests) accept arbitrary
shapes via a zero-padded trailing partial block.

See ``docs/wire.md`` for the full guide (sweep gating, the
``wire_tol`` budget knob, agree-then-persist flow).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# absmax guard: a zero block must decode to zeros, not NaN
_EPS = 1e-12

# bfloat16 has 7 explicit mantissa bits -> round-to-nearest relative
# error <= 2^-8; the absolute term covers f32 values below bf16's
# smallest subnormal (which flush to zero on cast)
BF16_REL_BOUND = 2.0 ** -8
BF16_ABS_GUARD = 1e-38

# int8 absmax: scale = absmax/127, round error <= scale/2 = absmax/254
INT8_REL_BOUND = 0.5 / 127.0


def interleave_complex(x):
    """Complex (..., n) -> real (..., 2n) with re/im interleaved."""
    parts = jnp.stack([jnp.real(x), jnp.imag(x)], axis=-1)
    return parts.reshape(*x.shape[:-1], 2 * x.shape[-1]).astype(jnp.float32)


def deinterleave_complex(y):
    """Inverse of ``interleave_complex``: real (..., 2n) -> complex."""
    p = y.reshape(*y.shape[:-1], y.shape[-1] // 2, 2)
    return p[..., 0] + 1j * p[..., 1]


def nblocks(n: int, block: Optional[int]) -> int:
    """Closed-form scale count for a length-``n`` last axis:
    ``ceil(n / block)``, or 1 when ``block`` is None (one scale spans
    the whole axis)."""
    if block is None:
        return 1
    return -(-int(n) // int(block))


class WireCodec:
    """One compressed wire format.

    ``encode(x)`` returns the tuple of arrays that travel (payload
    first); ``decode(parts, dtype)`` reconstructs. Every part has the
    payload's rank, so an exchange applies the SAME split/concat axes
    to each. ``encode_wire`` is the exchange-side entry point: it
    additionally enforces the block-alignment contract (exact
    divisibility) so blocks stay atomic through a tiled all_to_all.
    """

    name: str = "?"

    def encode(self, x) -> Tuple:
        raise NotImplementedError

    def decode(self, parts: Tuple, dtype=jnp.float32):
        raise NotImplementedError

    def encode_wire(self, x) -> Tuple:
        return self.encode(x)

    def max_error(self, x):
        """Elementwise bound on ``|decode(encode(x)) - x|`` for real
        ``x`` (for complex payloads, apply to the interleaved view)."""
        raise NotImplementedError

    def wire_bytes(self, shape, dtype=jnp.float32) -> int:
        """Bytes this codec puts on the wire for one array."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Bf16Codec(WireCodec):
    """The existing reduced-precision wire as a codec: one bfloat16
    cast, no side payload. Error: ``2^-8 · |x|`` per element."""
    name: str = "bf16"

    def encode(self, x):
        if jnp.iscomplexobj(x):
            x = interleave_complex(x)
        return (x.astype(jnp.bfloat16),)

    def decode(self, parts, dtype=jnp.float32):
        (y,) = parts
        if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
            return deinterleave_complex(y.astype(jnp.float32)).astype(dtype)
        return y.astype(dtype)

    def max_error(self, x):
        return BF16_REL_BOUND * jnp.abs(x) + BF16_ABS_GUARD

    def wire_bytes(self, shape, dtype=jnp.float32) -> int:
        n = int(np.prod(shape, dtype=np.int64))
        if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
            n *= 2
        return 2 * n


@dataclasses.dataclass(frozen=True)
class Int8Codec(WireCodec):
    """Absmax int8 with per-block f32 scales over the last axis.

    ``block=None`` scales each whole last-axis row with ONE factor
    (the historical ``optim/compress.py`` scheme, per row instead of
    per leaf); ``block=B`` scales every B-element chunk independently,
    so an outlier only coarsens its own block's grid. Error bound:
    ``|decode(encode(x)) - x| <= absmax_span / 254`` per element,
    where the span is the element's scaling block.
    """
    name: str = "int8"
    block: Optional[int] = None

    def _blocked(self, x):
        """(padded blocks view (..., nb, b), true last extent)."""
        n = x.shape[-1]
        b = n if self.block is None else int(self.block)
        nb = nblocks(n, b)
        pad = nb * b - n
        if pad:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        return x.reshape(*x.shape[:-1], nb, b), n

    def block_scales(self, x):
        """The per-block scale array (shape ``x.shape[:-1] + (nb,)``)."""
        blocks, _ = self._blocked(jnp.asarray(x, jnp.float32))
        absmax = jnp.max(jnp.abs(blocks), axis=-1)
        return (absmax + _EPS) / 127.0

    def encode(self, x):
        if jnp.iscomplexobj(x):
            x = interleave_complex(x)
        x = jnp.asarray(x, jnp.float32)
        blocks, n = self._blocked(x)
        absmax = jnp.max(jnp.abs(blocks), axis=-1)
        scales = ((absmax + _EPS) / 127.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(blocks / scales[..., None]), -127, 127)
        q = q.reshape(*x.shape[:-1], blocks.shape[-2] * blocks.shape[-1])
        return q[..., :n].astype(jnp.int8), scales

    def encode_wire(self, x):
        n = int(x.shape[-1])
        if self.block is not None and n % int(self.block):
            raise ValueError(
                f"wire codec {self.name}: last-axis extent {n} is not a "
                f"multiple of the block size {self.block} — blocks would "
                f"not stay atomic through the tiled all_to_all")
        return self.encode(x)

    def decode(self, parts, dtype=jnp.float32):
        q, scales = parts
        n = q.shape[-1]
        nb = scales.shape[-1]
        # block span: the codec's own block size, or (block=None) the
        # exact per-scale span the exchange produced — a concat along
        # the last axis turns one scale per source row into nb scales
        # each spanning that source's row extent
        b = int(self.block) if self.block is not None else n // max(nb, 1)
        rep = jnp.repeat(scales.astype(jnp.float32), b, axis=-1)[..., :n]
        out = q.astype(jnp.float32) * rep
        if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
            return deinterleave_complex(out).astype(dtype)
        return out.astype(dtype)

    def max_error(self, x):
        scales = self.block_scales(x)
        n = x.shape[-1]
        b = n if self.block is None else int(self.block)
        return 0.5 * jnp.repeat(scales, b, axis=-1)[..., :n]

    def wire_bytes(self, shape, dtype=jnp.float32) -> int:
        shape = tuple(int(s) for s in shape)
        last = shape[-1] if shape else 1
        rows = int(np.prod(shape[:-1], dtype=np.int64)) if len(shape) > 1 \
            else 1
        if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
            last *= 2
        return rows * last + 4 * rows * nblocks(last, self.block)


def exact_bytes(shape, dtype=jnp.float32) -> int:
    """The exact-wire baseline: one f32/complex64/... copy."""
    return (int(np.prod(shape, dtype=np.int64))
            * jnp.dtype(dtype).itemsize)


# ---------------------------------------------------------------------------
# Wire packing — all encoded parts ride ONE collective
# ---------------------------------------------------------------------------

def _as_bytes(p):
    """View an array as uint8 along a widened last axis."""
    dt = jnp.dtype(p.dtype)
    b = jax.lax.bitcast_convert_type(p, jnp.uint8)
    if dt.itemsize == 1:
        return b
    return b.reshape(*p.shape[:-1], p.shape[-1] * dt.itemsize)


def _from_bytes(b, dtype):
    """Inverse of ``_as_bytes``: uint8 (..., nbytes) -> dtype array."""
    dt = jnp.dtype(dtype)
    if dt.itemsize == 1:
        return jax.lax.bitcast_convert_type(b, dt)
    v = b.reshape(*b.shape[:-1], b.shape[-1] // dt.itemsize, dt.itemsize)
    return jax.lax.bitcast_convert_type(v, dt)


def pack_wire(parts: Tuple, shards: int, *, split_last: bool,
              concat_last: bool) -> Tuple:
    """Pack encoded parts into ONE uint8 buffer for a single tiled
    all_to_all.

    The exchange that moves a codec's parts (int8 payload, f32 scales)
    as separate collectives is a hazard on the CPU gloo transport:
    concurrently-scheduled collectives with different message sizes on
    the same mesh axis can cross-pair and abort. Packing makes the
    whole codec wire one collective of one size.

    Alignment contract: when the all_to_all SPLITS the last axis
    (``split_last``), the packed last axis is laid out as ``shards``
    contiguous segments, each holding one shard's slice of every part
    — so the tiled split hands every shard exactly its own payload and
    scale bytes. Each part's last-axis extent must then be a multiple
    of ``shards`` (the same feasibility rule the separate exchanges
    had); violations raise ``ValueError`` at trace time. When the
    exchange CONCATS along the last axis, the received buffer holds
    ``shards`` packed segments, which ``unpack_wire`` re-splices into
    per-part arrays matching what per-part exchanges would have
    produced.

    Returns ``(packed, meta)``; pass ``meta`` (static python data) to
    ``unpack_wire`` on the far side of the exchange.
    """
    k = int(shards) if split_last else 1
    segs = []
    spec = []
    for p in parts:
        n = int(p.shape[-1])
        if n % k:
            raise ValueError(
                f"wire pack: part last-axis extent {n} is not a "
                f"multiple of the {k} exchange shards — parts would "
                f"not stay aligned through the tiled all_to_all")
        v = p.reshape(*p.shape[:-1], k, n // k)
        segs.append(_as_bytes(v))
        spec.append((jnp.dtype(p.dtype).name, n))
    packed = jnp.concatenate(segs, axis=-1)
    packed = packed.reshape(*packed.shape[:-2],
                            packed.shape[-2] * packed.shape[-1])
    m = int(shards) if concat_last else 1
    return packed, (tuple(spec), k, m)


def unpack_wire(packed, meta) -> Tuple:
    """Inverse of ``pack_wire``, applied AFTER the exchange: recover
    the per-part arrays exactly as per-part all_to_alls would have
    delivered them."""
    spec, k, m = meta
    seg_bytes = sum(jnp.dtype(d).itemsize * n for d, n in spec) // k
    seg = packed.reshape(*packed.shape[:-1], m, seg_bytes)
    parts = []
    off = 0
    for dtype, n in spec:
        nb = jnp.dtype(dtype).itemsize * n // k
        piece = _from_bytes(seg[..., off:off + nb], dtype)
        off += nb
        parts.append(piece.reshape(*piece.shape[:-2],
                                   piece.shape[-2] * piece.shape[-1]))
    return tuple(parts)


# ---------------------------------------------------------------------------
# Registry — codec names are the hashable plan-knob currency
# ---------------------------------------------------------------------------

DEFAULT_BLOCK = 64

_BLOCK_NAME = re.compile(r"^int8_block(\d+)$")

_REGISTRY: Dict[str, WireCodec] = {
    "bf16": Bf16Codec(),
    "int8": Int8Codec("int8", None),
    f"int8_block{DEFAULT_BLOCK}": Int8Codec(f"int8_block{DEFAULT_BLOCK}",
                                            DEFAULT_BLOCK),
}


def get_codec(name: str) -> WireCodec:
    """Resolve a codec name (``bf16`` / ``int8`` / ``int8_block<B>``).
    Raises ``ValueError`` for anything else — dtype names like
    ``"bfloat16"`` are NOT codecs; they stay on the plain
    ``wire_dtype`` cast path."""
    codec = _REGISTRY.get(name)
    if codec is not None:
        return codec
    m = _BLOCK_NAME.match(name or "")
    if m:
        b = int(m.group(1))
        if b < 1:
            raise ValueError(f"wire codec block size must be >= 1: {name}")
        codec = Int8Codec(name, b)
        _REGISTRY[name] = codec
        return codec
    raise ValueError(
        f"unknown wire codec {name!r}; known: {sorted(_REGISTRY)} "
        f"plus any int8_block<B>")


def is_codec(name) -> bool:
    """True when ``name`` names a wire codec (vs a plain wire dtype)."""
    if not isinstance(name, str):
        return False
    return name in _REGISTRY or bool(_BLOCK_NAME.match(name))


def codec_names() -> Tuple[str, ...]:
    """The stock codec names (stable order, for sweeps and docs)."""
    return ("bf16", "int8", f"int8_block{DEFAULT_BLOCK}")
