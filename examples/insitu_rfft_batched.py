"""Batched real-input in-situ chain — many fields per step, one plan.

A simulation rarely publishes one field: velocity components, pressure,
tracers all need the same spectral processing every step. This example
runs the paper's fwd → bandpass → inv chain over a STACK of real fields
with a single cached, batched r2c/c2r plan pair:

  * ``real=True``      — Hermitian half-spectrum (r2c forward, c2r
                          back): half the FFT work and wire bytes
  * ``batch_ndim=1``   — the leading dim is a batch of fields sharing
                          one compiled executable
  * plan cache         — both endpoints and every later step reuse the
                          process-wide compiled plans (FFTW-style:
                          plan once, execute forever)

Run:  PYTHONPATH=src python examples/insitu_rfft_batched.py
(uses 8 host placeholder devices — set BEFORE jax import)
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core.fft.plan import plan_cache_stats
from repro.core.insitu.bridge import BridgeData, GridMeta
from repro.core.insitu.config import build_chain

mesh = make_mesh((8,), ("data",))
B, N0, N1 = 4, 128, 128            # 4 fields per step
grid = GridMeta(dims=(N0, N1))

rng = np.random.default_rng(0)
yy, xx = np.meshgrid(np.arange(N0), np.arange(N1), indexing="ij")
clean = np.stack([np.sin(2 * np.pi * k * (xx + 2 * yy) / N0) / k
                  for k in (2, 3, 4, 5)]).astype(np.float32)
fields = clean + 0.5 * rng.standard_normal((B, N0, N1)).astype(np.float32)

chain = build_chain({
    "mode": "insitu",
    "chain": [
        {"endpoint": "fft", "array": "field", "direction": "forward",
         "real": True, "batch_ndim": 1},
        {"endpoint": "bandpass", "array": "field", "keep_frac": 0.08,
         "use_kernel": False},
        {"endpoint": "fft", "array": "field", "direction": "backward",
         "real": True, "batch_ndim": 1},
    ],
}, mesh=mesh, grid=grid)

data = BridgeData(arrays={"field": jnp.asarray(fields)}, grid=grid)
out = chain.execute(data)

den = np.asarray(out.arrays["field"])
for b in range(B):
    mse0 = float(np.mean((fields[b] - clean[b]) ** 2))
    mse1 = float(np.mean((den[b] - clean[b]) ** 2))
    print(f"field {b}: MSE {mse0:.4f} -> {mse1:.4f} "
          f"({mse0 / mse1:.1f}x better)")
print("plan cache:", plan_cache_stats())
print("timings:", chain.marshaling_report()["timings_s"])
assert all(np.mean((den[b] - clean[b]) ** 2)
           < np.mean((fields[b] - clean[b]) ** 2) for b in range(B))
print("OK")
