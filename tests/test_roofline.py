"""Roofline extraction unit tests: HLO collective/traffic parsers, the
L-extrapolation, and MODEL_FLOPS accounting."""
import numpy as np

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch import roofline as rl

HLO = """
ENTRY %main {
  %p0 = bf16[256,1024]{1,0} parameter(0)
  %ar = bf16[256,1024]{1,0} all-reduce(bf16[256,1024]{1,0} %p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,4096]{1,0} all-gather(f32[64,256]{1,0} %x), replica_groups=[16,16]<=[256], dimensions={1}
  %rs = bf16[16,64]{1,0} reduce-scatter(bf16[256,64]{1,0} %y), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %a2a = f32[128,32]{1,0} all-to-all(f32[128,32]{1,0} %z), replica_groups={{0,1}}
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %w), source_target_pairs={{0,1}}
  %dot.1 = f32[128,64]{1,0} dot(f32[128,256]{1,0} %a, f32[256,64]{1,0} %b), lhs_contracting_dims={1}
}
"""


def test_collective_parser_kinds_and_factors():
    out = rl.collective_wire_bytes(HLO)
    n = 4
    ar = 2 * (n - 1) / n * 256 * 1024 * 2
    assert abs(out["all-reduce"] - ar) < 1
    ag = (16 - 1) / 16 * 64 * 4096 * 4
    assert abs(out["all-gather"] - ag) < 1
    rs = (16 - 1) * 16 * 64 * 2
    assert abs(out["reduce-scatter"] - rs) < 1
    a2a = (2 - 1) / 2 * 128 * 32 * 4
    assert abs(out["all-to-all"] - a2a) < 1
    cp = 8 * 8 * 4
    assert abs(out["collective-permute"] - cp) < 1
    total = ar + ag + rs + a2a + cp
    assert abs(out["total"] - total) < 1


def test_traffic_model_counts_dots():
    got = rl.hbm_traffic_model(HLO)
    dot = (128 * 256 + 256 * 64 + 128 * 64) * 4
    assert got >= dot
    # collectives are NOT in the traffic model
    assert got < dot + 1e4


def test_extrapolation():
    c0 = {"flops": 10.0, "bytes": 100.0, "trans": 0.0,
          "coll": {"all-reduce": 5.0, "total": 5.0}}
    c1 = {"flops": 14.0, "bytes": 160.0, "trans": 0.0,
          "coll": {"all-reduce": 8.0, "total": 8.0}}
    cell = rl.extrapolate(c0, c1, 10)
    assert cell.flops == 10 + 10 * 4
    assert cell.bytes_hbm == 100 + 10 * 60
    assert cell.coll_bytes == 5 + 10 * 3
    assert cell.dominant in ("compute", "memory", "collective")


def test_terms_and_dominant():
    cell = rl.CellCost(flops=rl.PEAK_FLOPS, bytes_hbm=0.0, coll_bytes=0.0,
                       coll_by_kind={})
    assert abs(cell.t_compute - 1.0) < 1e-9
    assert cell.dominant == "compute"


def test_model_flops_kinds():
    cfg = registry.get_config("qwen3-4b")
    n = cfg.param_count()
    tr = rl.model_flops(cfg, SHAPES["train_4k"])
    pf = rl.model_flops(cfg, SHAPES["prefill_32k"])
    de = rl.model_flops(cfg, SHAPES["decode_32k"])
    assert abs(tr - 6 * n * 256 * 4096) / tr < 1e-9
    assert abs(pf - 2 * n * 32 * 32768) / pf < 1e-9
    assert abs(de - 2 * n * 128) / de < 1e-9


def test_moe_uses_active_params():
    cfg = registry.get_config("grok-1-314b")
    tr = rl.model_flops(cfg, SHAPES["train_4k"])
    dense_equiv = 6 * cfg.param_count() * 256 * 4096
    assert tr < 0.5 * dense_equiv


def test_dtype_bytes_table():
    assert rl._shape_bytes("bf16", "2,3") == 12
    assert rl._shape_bytes("f32", "") == 4      # scalar
    assert rl._shape_bytes("s8", "100") == 100
