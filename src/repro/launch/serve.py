"""Serving driver: prefill + batched decode with the KV-cache engine.

``python -m repro.launch.serve --arch qwen3-4b --reduced --tokens 32``
runs prompt prefill then greedy decode for a batch of requests,
reporting per-token latency. The same entry point drives the full
configs on a production mesh (decode cells of the dry-run prove those
shardings compile).

``--monitor-every K`` attaches a **pipelined in-situ chain** to the
request loop (stats → FFT → bandpass on the last-token logits, host
writer at the tail): every K decode steps a logits snapshot is
*submitted to an* :class:`~repro.serve.fft_engine.FFTServeEngine`
monitor bucket, and the engine coalesces ``--monitor-batch`` snapshots
into ONE batched field handed to the chain — *in-flight batching*: the
decode loop never blocks on the monitor (the chain's device stages
ride async dispatch, the host writer runs on the pipeline worker, and
the engine's bounded admission backpressures only if analysis falls
far behind). The trailing partial batch goes through the same
``engine.flush()`` path as the in-loop submits — there is exactly one
flush code path. The report gains the chain's overlap-efficiency
numbers plus the engine's coalescing/queue accounting, and is emitted
as BENCH rows (``--bench-out``, trend-gateable) rather than a bare
JSON dump.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import registry
from repro.core.fft import plan as plan_mod
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.runtime.cluster import (add_cluster_args, config_from_args,
                                   init_cluster)
from repro.sharding.policy import make_policy


def _build_monitor(args, cfg, bridge=None):
    """The pipelined in-situ chain the decode loop feeds: one batched
    field of ``--monitor-batch`` stacked logit snapshots per submit.
    Warmed on zeros before returning — trace/compile and the chain's
    device-probe calibration must not land inside the timed decode
    loop. With ``bridge`` (the M→N in-transit split) the warm-up also
    rides the bridge, so the analysis chain compiles against
    consumer-mesh inputs from the first real submit."""
    from pathlib import Path

    from repro.core.insitu.bridge import BridgeData, GridMeta
    from repro.core.insitu.config import build_chain

    chain = build_chain({
        "mode": "pipelined",
        "chain": [
            {"endpoint": "stats", "array": "field"},
            {"endpoint": "fft", "array": "field", "direction": "forward",
             "local": True, "batch_ndim": 1},
            {"endpoint": "bandpass", "array": "field", "keep_frac": 0.25},
            {"endpoint": "writer", "array": "insitu_stats",
             "out_dir": args.monitor_dir, "prefix": "logit_stats"},
        ],
    }, mesh=None, grid=GridMeta((args.batch, cfg.vocab_size)))
    warm = BridgeData(
        arrays={"field": jnp.zeros(
            (args.monitor_batch, args.batch, cfg.vocab_size),
            jnp.float32)},
        step=0, meta={"primary": "field"})
    if bridge is not None:
        # send() is collective — every process calls it — but only
        # consumer participants receive the arrays (host transport
        # hands producers None leaves), so only they warm the chain
        warm = bridge.send(warm)
        if not bridge.is_consumer():
            bridge.reset_stats()  # warm-up must not skew the report
            return chain
    chain.execute(warm)           # compile the fused device program
    chain.execute(warm)           # consume the device-probe block
    chain.drain()
    chain.reset_stats()
    if bridge is not None:
        bridge.reset_stats()      # warm-up must not skew the report
    writer = chain.endpoints[-1]  # drop the warm-up artifacts
    for f in writer.written:
        Path(f).unlink(missing_ok=True)
    writer.written.clear()
    return chain


def _attach_monitor_engine(args, chain, bridge=None):
    """Wire the chain behind an :class:`FFTServeEngine` monitor bucket:
    the decode loop submits raw in-flight snapshots; the engine
    coalesces ``--monitor-batch`` of them into one stacked BridgeData
    per chain execute. Returns the engine (manual tick mode — the
    driver steps it, keeping ``chain.execute`` on the decode thread
    inside the active mesh context)."""
    from repro.core.insitu.bridge import BridgeData
    from repro.serve.fft_engine import FFTServeEngine

    def execute_batch(payloads, step_idx):
        field = jnp.stack(list(payloads))
        payload = BridgeData(arrays={"field": field}, step=step_idx,
                             meta={"primary": "field"})
        if bridge is not None:
            payload = bridge.send(payload)
            if not bridge.is_consumer():
                return None       # producers hold None leaves, no chain
        chain.execute(payload)
        return None

    engine = FFTServeEngine(max_pending=4 * args.monitor_batch,
                            linger_s=float("inf"))  # flush-at only
    engine.register_bucket("monitor", execute_batch,
                           flush_at=args.monitor_batch)
    return engine


def _emit_report_rows(report: dict, path: str) -> None:
    """End-of-run report as BENCH rows (the trend-gateable schema of
    ``benchmarks/run.py``) instead of a bare JSON print: one row per
    headline latency, the full report under ``derived``."""
    from pathlib import Path

    rows = {
        "serve_run_prefill": {
            "us_per_call": round(report["prefill_ms"] * 1e3, 1),
            "derived": f"batch={report['batch']}"},
        "serve_run_decode_token": {
            "us_per_call": round(report["decode_ms_per_token"] * 1e3, 1),
            "derived": f"tokens_per_s={report['tokens_per_s']}"},
    }
    if "monitor" in report:
        mon = report["monitor"]
        rows["serve_run_monitor_submit"] = {
            "us_per_call": round(mon["engine"]["submit_us_p50"], 1),
            "derived": (f"submits={mon['submits']} "
                        f"coalesced={mon['snapshots']}->"
                        f"{mon['submits']}")}
    out = Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(
        {"rows": rows, "unit": "us_per_call",
         "source": "repro.launch.serve", "report": report},
        indent=2, sort_keys=True) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--monitor-every", type=int, default=0,
                    help="attach the pipelined in-situ logits monitor "
                         "every K decode steps (0 = off)")
    ap.add_argument("--monitor-batch", type=int, default=4,
                    help="snapshots batched into one in-flight submit")
    ap.add_argument("--monitor-dir", default="results/serve_monitor")
    ap.add_argument("--bench-out", default="results/BENCH_serve_run.json",
                    help="end-of-run report lands here as BENCH rows "
                         "(trend_check-compatible; '' disables)")
    ap.add_argument("--wisdom", default=None, metavar="FILE",
                    help="persistent autotune wisdom file: measured "
                         "sweep winners are read at bring-up and new "
                         "ones persisted, so restarts skip the timed "
                         "sweeps (overrides REPRO_WISDOM_FILE; "
                         "docs/wisdom.md)")
    ap.add_argument("--wisdom-mode", default="readwrite",
                    choices=("off", "read", "readwrite"),
                    help="read = consult wisdom but never write it")
    ap.add_argument("--transit-consumers", type=int, default=0,
                    metavar="N",
                    help="in-transit M→N split: decode on all but the "
                         "last N devices and run the logits monitor on "
                         "a disjoint N-device consumer mesh (0 = "
                         "analyze in place). Multi-process clusters: "
                         "every process must keep at least one decode "
                         "device or the run aborts (docs/multihost.md, "
                         "subset collectives)")
    ap.add_argument("--elastic", action="store_true",
                    help="put the monitor's consumer mesh under an "
                         "ElasticController: consumer ranks heartbeat "
                         "at monitor cadence and a rank missing its "
                         "lease is rescaled away without restarting "
                         "decode (docs/elastic.md; requires "
                         "--transit-consumers)")
    ap.add_argument("--elastic-lease", type=float, default=30.0,
                    metavar="SECONDS",
                    help="heartbeat lease; a consumer rank missing 3 "
                         "leases is declared dead")
    add_cluster_args(ap)
    args = ap.parse_args(argv)
    if args.wisdom:
        # before any measured planning (restarts warm-start from it)
        plan_mod.set_wisdom(args.wisdom, args.wisdom_mode)
    # multi-process bring-up (env/flag-driven; single-process no-op)
    init_cluster(config_from_args(args))

    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    assert cfg.family != "encdec", "use whisper serve example for enc-dec"
    transit_bridge = None
    elastic = None
    if args.transit_consumers:
        # M→N in-transit: decode on the producer mesh, monitor on the
        # disjoint consumer mesh
        if args.elastic:
            # the controller duck-types the bridge: monitor warm-up and
            # every engine submit route to the newest generation's mesh
            from repro.launch.mesh import make_elastic_setup
            mesh, elastic = make_elastic_setup(
                args.transit_consumers, noun="decode",
                lease=args.elastic_lease)
            transit_bridge = elastic
        else:
            from repro.launch.mesh import make_transit_setup
            mesh, transit_bridge = make_transit_setup(
                args.transit_consumers, noun="decode")
    elif args.elastic:
        raise SystemExit("--elastic requires --transit-consumers N "
                         "(there is no consumer mesh to rescale)")
    else:
        mesh = make_host_mesh()
    policy = make_policy(mesh, global_batch=args.batch)

    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(cfg, key, jnp.float32)
    cache_len = args.prompt_len + args.tokens

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    prefill = jax.jit(lambda p, b: lm.prefill(cfg, p, b, policy,
                                              cache_len=cache_len))
    decode = jax.jit(lambda p, t, s: lm.decode_step(cfg, p, t, s, policy))

    monitor = (_build_monitor(args, cfg, transit_bridge)
               if args.monitor_every else None)
    engine = (_attach_monitor_engine(args, monitor, transit_bridge)
              if monitor is not None else None)
    snapshots = 0

    with compat.set_mesh(mesh):
        t0 = time.perf_counter()
        logits, state = prefill(params, {"tokens": prompts})
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        out_tokens = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t0 = time.perf_counter()
        for step in range(args.tokens):
            out_tokens.append(np.asarray(tok))
            logits, state = decode(params, tok, state)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
                     .astype(jnp.int32)
            if engine is not None and step % args.monitor_every == 0:
                # submit the (still in-flight) logits to the monitor
                # bucket; the engine coalesces --monitor-batch of them
                # into ONE batched chain execute per tick — the decode
                # loop never waits for the analysis
                engine.submit(logits[:, -1], bucket="monitor")
                snapshots += 1
                engine.step()
                if elastic is not None:
                    # lease renewal + failure poll at monitor cadence;
                    # tick() is collective and every process reaches
                    # this point at the same decode step
                    elastic.heartbeat_all()
                    elastic.tick()
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0
        if engine is not None:
            # trailing partial batch: a different leading dim means a
            # fresh trace — same flush helper as the in-loop ticks,
            # forced, outside the timed decode window
            engine.flush()
            engine.drain()

    gen = np.concatenate(out_tokens, axis=1)
    report = {
        "arch": cfg.name,
        "batch": args.batch,
        "prefill_ms": round(t_prefill * 1e3, 2),
        "decode_ms_per_token": round(t_decode / args.tokens * 1e3, 3),
        "tokens_per_s": round(args.batch * args.tokens / t_decode, 1),
        "sample": gen[0, :8].tolist(),
    }
    if monitor is not None:
        monitor.drain()
        erep = engine.report()
        engine.stop()
        mrep = monitor.marshaling_report()
        files = monitor.finalize()["writer"]["files"]
        pipe = mrep.get("pipeline", {})
        report["monitor"] = {
            "submits": erep["batching"]["executes"],
            "snapshots": snapshots,
            "snapshot_batch": args.monitor_batch,
            "files": len(files),
            "overlap_efficiency": round(
                pipe.get("overlap_efficiency", 0.0), 3),
            "host_busy_ms": round(pipe.get("host_busy_s", 0.0) * 1e3, 2),
            "backpressure_ms": round(
                pipe.get("backpressure_s", 0.0) * 1e3, 2),
            "engine": {
                "batched_execute_ratio":
                    erep["batching"]["batched_execute_ratio"],
                "submit_us_p50": erep["latency_ms"]["p50"] * 1e3,
                "submit_us_p99": erep["latency_ms"]["p99"] * 1e3,
                "queue_depth_max": erep["queue"]["depth_max"],
            },
        }
    if transit_bridge is not None:
        # controller.report() nests the live bridge's transit accounting
        report["elastic" if elastic is not None else "transit"] = \
            transit_bridge.report()
    if args.bench_out and jax.process_index() == 0:
        _emit_report_rows(report, args.bench_out)
        print(f"serve: decode {report['decode_ms_per_token']} ms/token, "
              f"{report['tokens_per_s']} tok/s -> {args.bench_out}")
    else:
        print(json.dumps(report))
    return report


if __name__ == "__main__":
    main()
