"""The SENSEI FFT endpoint — the paper's primary contribution (§2.2).

Configured exactly like the paper's XML (mesh / array / direction), it
marshals the bridge's named array into split-plane spectral form, runs
the planned distributed transform (any ``schedule.CAPS`` decomposition
— slab / slab3d / pencil / pencil_tf / pencil2d / fourstep1d,
inferred by grid rank and mesh when ``decomp`` is omitted; FFTW's
plan-execute lifecycle via the cached ``FFTPlan``), and republishes
the result on the bridge for downstream consumers. Forward sets
``domain="spectral"`` + the layout tag; backward restores spatial
data.

Beyond the paper's complex endpoint:

* ``real=True`` uses the r2c/c2r half-spectrum plans (``plan_rfft``) —
  half the local FFT work and half the all_to_all wire bytes for the
  real simulation fields the paper actually targets, on EVERY
  decomposition but ``fourstep1d`` (slab3d on 1-axis meshes and the
  digit-permuted pencil_tf included). Forward publishes the
  half-spectrum pair and tags the layout ``*-half``; ``Bandpass``
  gathers/slices its mask to match any such tag automatically.
* ``backend="measure"`` autotunes the plan on first use (FFTW_MEASURE).
* ``batch_ndim=k`` transforms arrays with ``k`` leading batch dims
  (many fields per step) under one compiled plan.

Plans come from the process-wide plan cache, so chains rebuilt every
step (or many endpoints over the same grid) share one compiled
executable.

Layout contracts: forward output order depends on the decomposition
(``transposed`` / ``rotated`` / ``fourstep`` / ``rotated-fourstep``,
each ``-half`` for r2c), and the cyclic/digit-permuted decompositions
constrain the SPATIAL side too — the full contract, with a worked
8-point example of the cyclic and digit-permuted orders, is
``docs/layouts.md``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.fft.plan import BACKWARD, FORWARD, plan_dft, plan_rfft
from repro.core.insitu.bridge import BridgeData
from repro.core.insitu.endpoint import Endpoint

_LAYOUT = {"slab": "transposed", "slab3d": "transposed",
           "pencil": "rotated", "pencil_tf": "rotated-fourstep",
           "pencil2d": "transposed",   # natural order; only the
           "fourstep1d": "fourstep"}   # sharding is 2-axis-transposed

# decompositions whose SPATIAL side is the cyclic layout (global element
# g = m·P + p on shard p along the first sharded grid axis) — their
# forward input must be cyclic-ordered, and their backward output IS
# cyclic, not natural
_CYCLIC_DECOMPS = ("pencil_tf", "fourstep1d")


class FFTEndpoint(Endpoint):
    """Planned distributed (or ``local=True`` single-device) FFT as a
    chain stage; see the module docstring and ``docs/layouts.md`` for
    the output-layout contract per decomposition."""

    name = "fft"

    def __init__(self, *, array: str = "field", direction: str = "forward",
                 backend: str = "auto", decomp: Optional[str] = None,
                 overlap_chunks: int = 0, local: bool = False,
                 real: bool = False, batch_ndim: int = 0,
                 wire_dtype: Optional[str] = None):
        super().__init__(array=array, direction=direction)
        self.array = array
        self.direction = FORWARD if direction == "forward" else BACKWARD
        self.backend = backend
        self.decomp = decomp
        self.overlap_chunks = overlap_chunks
        self.local = local              # single-device jnp path (tests)
        self.real = real
        self.batch_ndim = batch_ndim
        self.wire_dtype = wire_dtype
        self.plan = None
        self._grid_dims = None

    def initialize(self, mesh=None, grid=None):
        """Build (or fetch from the process-wide cache) the plan for
        ``grid.dims`` on ``mesh``; ``local=True``/no-mesh chains skip
        planning and transform with ``jnp.fft`` at execute time."""
        if grid is not None:
            self._grid_dims = tuple(grid.dims)
        if self.local or mesh is None:
            return
        assert grid is not None, "FFTEndpoint needs grid dims to plan"
        planner = plan_rfft if self.real else plan_dft
        self.plan = planner(grid.dims, self.direction, mesh,
                            decomp=self.decomp, backend=self.backend,
                            overlap_chunks=self.overlap_chunks,
                            batch_ndim=self.batch_ndim,
                            wire_dtype=self.wire_dtype)

    # -- execution -------------------------------------------------------------
    def _run_local(self, re, im):
        # transform only the trailing grid dims — leading batch dims are
        # independent fields, exactly like the distributed plans
        nd = re.ndim - self.batch_ndim
        axes = tuple(range(-nd, 0))
        if self.real and self.direction == FORWARD:
            z = jnp.fft.rfftn(re, axes=axes)
            return (jnp.real(z).astype(jnp.float32),
                    jnp.imag(z).astype(jnp.float32)), "natural-half"
        if self.real and self.direction == BACKWARD:
            s = self._grid_dims
            y = jnp.fft.irfftn(re + 1j * im, s=s, axes=axes)
            return (y.astype(jnp.float32),
                    jnp.zeros_like(y, jnp.float32)), "natural"
        x = re + 1j * im
        out = (jnp.fft.ifftn(x, axes=axes)
               if self.direction == BACKWARD
               else jnp.fft.fftn(x, axes=axes))
        return (jnp.real(out).astype(jnp.float32),
                jnp.imag(out).astype(jnp.float32)), "natural"

    def execute(self, data: BridgeData) -> BridgeData:
        """Transform ``array`` and republish it with the matching
        ``domain``/``layout`` tags (see ``docs/layouts.md``); rejects
        non-cyclic spatial input for the cyclic-contract decomps."""
        if (self.plan is not None and self.direction == FORWARD
                and self.plan.decomp in _CYCLIC_DECOMPS
                and data.layout != "cyclic"):
            raise ValueError(
                f"decomp={self.plan.decomp!r} transforms the CYCLIC "
                f"spatial layout (got layout={data.layout!r}): reorder "
                f"the field with distributed.cyclic_order along the "
                f"first sharded grid axis and publish it with "
                f"BridgeData.layout='cyclic'")
        if self.plan is None:
            re, im = data.get_pair(self.array)
            (r, i), layout = self._run_local(re, im)
        elif self.real and self.direction == FORWARD:
            x = data.arrays[self.array]
            if isinstance(x, tuple):
                x = x[0]              # real field traveling as (x, 0)
            r, i = self.plan.execute(x)
            layout = _LAYOUT[self.plan.decomp] + "-half"
        elif self.real:               # c2r backward: returns the field
            re, im = data.get_pair(self.array)
            r = self.plan.execute(re, im)
            i = jnp.zeros_like(r)
            layout = "natural"
        else:
            # already-compiled distributed transform; zero-copy handoff
            re, im = data.get_pair(self.array)
            r, i = self.plan.execute(re, im)
            layout = _LAYOUT[self.plan.decomp] \
                if self.direction == FORWARD else "natural"

        arrays = dict(data.arrays)
        if self.direction == FORWARD:
            arrays[self.array] = (r, i)
            return data.replace(arrays=arrays, domain="spectral",
                                layout=layout)
        arrays[self.array] = r        # real field (imag ~ 0 for real input)
        arrays[self.array + "_imag"] = i
        spatial = "cyclic" if (self.plan is not None
                               and self.plan.decomp in _CYCLIC_DECOMPS) \
            else "natural"
        return data.replace(arrays=arrays, domain="spatial",
                            layout=spatial)
