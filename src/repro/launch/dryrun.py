import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (no mismatch / unsupported collective),
  * the program fits (memory_analysis per chip),
  * and extracts roofline terms (cost_analysis + HLO collective parse,
    with the L∈{0,1,full} scan-trip extrapolation — see roofline.py).

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all               # every cell, 1-pod + 2-pod
  python -m repro.launch.dryrun --all --mesh pod1   # single-pod only

Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro import compat
from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch import specs as specs_mod
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import blocks as blk

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _with_groups(cfg, groups: int):
    """Copy of cfg with the scan trip count forced to `groups`."""
    period = len(cfg.layer_pattern)
    reps = {"num_layers": period * groups}
    if cfg.family == "encdec":
        reps.update(encoder_layers=groups, decoder_layers=groups)
    return dataclasses.replace(cfg, **reps)


def lower_cell(cfg, shape, mesh, *, multi_pod: bool, **overrides):
    built, policy = specs_mod.build_cell(cfg, shape, mesh,
                                         multi_pod=multi_pod, **overrides)
    jitted = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                     out_shardings=built.get("out_shardings"),
                     donate_argnums=built["donate_argnums"])
    with compat.set_mesh(mesh):
        lowered = jitted.lower(*built["args"])
        compiled = lowered.compile()
    return built, compiled


def run_cell(arch: str, shape_name: str, mesh_name: str,
             *, full_roofline: bool = True, **overrides) -> dict:
    cfg = registry.get_config(arch)
    if "moe_mode" in overrides:     # §Perf: EP↔TP expert-sharding probe
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         mode=overrides.pop("moe_mode")))
    if "capacity_factor" in overrides:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=overrides.pop("capacity_factor")))
    shape = SHAPES[shape_name]
    multi_pod = mesh_name == "pod2"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256

    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": chips, "status": "ok"}
    try:
        built, compiled = lower_cell(cfg, shape, mesh, multi_pod=multi_pod,
                                     **overrides)
        result["meta"] = built["meta"]
        result["memory"] = rl.memory_report(compiled)
        cL = rl.raw_costs(compiled)
        result["raw_cost_full"] = {k: v for k, v in cL.items()}

        if full_roofline:
            trips = (cfg.encoder_layers if cfg.family == "encdec"
                     else blk.n_groups(cfg))
            # Roofline compiles force microbatches=1: a second (microbatch)
            # scan would break the single-loop L-extrapolation, and the
            # micro=1 step is the bandwidth-optimal variant of the same
            # algorithm. The full artifact above keeps the real microbatch
            # count for the memory report.
            ro = dict(overrides)
            if shape.kind == "train":
                ro["microbatches"] = 1
            costs = {}
            for g in (0, 1):
                _, cg = lower_cell(_with_groups(cfg, g), shape, mesh,
                                   multi_pod=multi_pod, **ro)
                costs[g] = rl.raw_costs(cg)
            cell = rl.extrapolate(costs[0], costs[1], trips)
            result["roofline"] = cell.to_dict()
            result["roofline"]["trips"] = trips
            mf = rl.model_flops(cfg, shape, per_chip=True, chips=chips)
            result["roofline"]["model_flops_per_chip"] = mf
            result["roofline"]["useful_ratio"] = (
                mf / cell.flops if cell.flops else 0.0)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["compile_seconds"] = round(time.time() - t0, 1)
    return result


def save(result: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}.json"
    (RESULTS / name).write_text(json.dumps(result, indent=2, default=str))
    mem = result.get("memory", {}).get("total_hbm_per_chip", 0) / 2**30
    dom = result.get("roofline", {}).get("dominant", "-")
    print(f"[{result['status']:5s}] {result['arch']:16s} "
          f"{result['shape']:12s} {result['mesh']}  "
          f"hbm/chip={mem:6.2f}GiB dom={dom:10s} "
          f"t={result['compile_seconds']}s", flush=True)
    if result["status"] == "error":
        print("   ", result["error"].splitlines()[0][:160], flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["pod1", "pod2",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(a, s.name) for a in registry.list_archs()
                for s in registry.cells(a)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    for arch, shape in todo:
        for mesh_name in meshes:
            out = (RESULTS /
                   f"{arch}__{shape}__{mesh_name}.json")
            if args.skip_existing and out.exists():
                prev = json.loads(out.read_text())
                if prev.get("status") == "ok":
                    continue
            # roofline terms are a single-pod report; pod2 is the
            # sharding-coherence proof for the pod axis
            full = (mesh_name == "pod1") and not args.no_roofline
            save(run_cell(arch, shape, mesh_name, full_roofline=full))


if __name__ == "__main__":
    main()
