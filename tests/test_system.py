"""End-to-end behaviour of the paper's system: the full multi-stage
in-situ workflow (paper Fig. 2), training with the in-situ spectral
monitor attached, and the serve path — each through the public API."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.insitu.adaptors import RadiatingSourceAdaptor
from repro.core.insitu.config import build_chain
from repro.data import synthetic
from repro.models import lm
from repro.optim.adamw import AdamW, warmup_cosine
from repro.train import step as train_step_mod


def test_paper_fig2_workflow_stages(tmp_path):
    """Producer → FFT → bandpass → iFFT → visualize, checking each stage's
    domain/layout transitions like the paper's Fig. 2 panels."""
    src = RadiatingSourceAdaptor(dims=(200, 200))
    data = src.produce(0)
    assert data.domain == "spatial"

    fwd = build_chain({"chain": [
        {"endpoint": "fft", "array": "field", "direction": "forward",
         "local": True}]}, None, data.grid)
    spec = fwd.execute(data)
    assert spec.domain == "spectral"                       # Fig. 2b
    re, im = spec.get_pair("field")
    assert re.shape == (200, 200)

    rest = build_chain({"chain": [
        {"endpoint": "bandpass", "array": "field", "keep_frac": 0.05},
        {"endpoint": "fft", "array": "field", "direction": "backward",
         "local": True},
        {"endpoint": "visualize", "array": "field",
         "out_dir": str(tmp_path)},
    ]}, None, data.grid)
    out = rest.execute(spec)
    assert out.domain == "spatial"                         # Fig. 2d
    clean = np.asarray(data.arrays["clean_reference"])
    noisy = np.asarray(data.arrays["field"])
    den = np.asarray(out.arrays["field"])
    assert np.mean((den - clean) ** 2) < 0.5 * np.mean(
        (noisy - clean) ** 2)
    assert rest.finalize()["visualize"]["files"]


def test_training_with_insitu_monitor():
    """The paper's technique as a first-class training feature: spectra
    computed in situ (inside the jitted step), loss decreases."""
    from repro.core.insitu.chain import InSituChain
    from repro.core.insitu.endpoints.spectral_monitor import (
        SpectralMonitorEndpoint)

    cfg = registry.get_reduced("qwen3-4b")
    opt = AdamW(warmup_cosine(5e-3, 2, 30))
    chain = InSituChain([SpectralMonitorEndpoint(nbins=8, max_tensors=2)])
    step_fn = train_step_mod.make_train_step(
        cfg, None, opt, loss_chunk=16,
        insitu_chain=chain.as_step_hook(), insitu_every=1)
    state = train_step_mod.init_train_state(cfg, opt, jax.random.PRNGKey(0),
                                            param_dtype=jnp.float32)
    losses = []
    for s in range(15):
        b = synthetic.batch_at(s, global_batch=4, seq_len=32,
                               vocab=cfg.vocab_size)
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
        spectra = m["insitu"]["insitu_grad_spectra"]
        assert np.all(np.isfinite(np.asarray(spectra)))
    assert losses[-1] < losses[0] - 0.3, losses


def test_serve_generates_consistently():
    """Greedy decode via the serve engine == greedy decode via repeated
    full forwards."""
    cfg = registry.get_reduced("qwen3-4b")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, jnp.float32)
    B, S, T = 1, 8, 6
    prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    logits, state = lm.prefill(cfg, params, {"tokens": prompt},
                               cache_len=S + T)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(T - 1):
        logits, state = lm.decode_step(
            cfg, params, jnp.asarray([[toks[-1]]], jnp.int32), state)
        toks.append(int(jnp.argmax(logits[0, -1])))

    seq = prompt
    ref = []
    for _ in range(T):
        x = lm.embed_inputs(cfg, params, {"tokens": seq})
        from repro.models import blocks as blk
        from repro.models.common import rms_norm
        pos = jnp.broadcast_to(jnp.arange(seq.shape[1]), seq.shape)
        h, _ = blk.stack_forward(cfg, params["blocks"], x, pos, None,
                                 None, remat=False)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps, plus_one=True)
        lg = jnp.einsum("d,dv->v", h[0, -1].astype(jnp.float32),
                        lm.head_weights(cfg, params).astype(jnp.float32))
        nxt = int(jnp.argmax(lg))
        ref.append(nxt)
        seq = jnp.concatenate([seq, jnp.asarray([[nxt]], jnp.int32)], 1)
    assert toks == ref, (toks, ref)
