"""Decoder blocks and scan-over-layers assembly.

Models repeat a *pattern period* of layers (e.g. gemma2 alternates
("swa","full"); zamba2 is five "ssm" layers then one "hybrid" slot that
invokes the shared attention block). Parameters for one period are
stacked with a leading ``n_groups`` dim under the "blocks" key and the
whole depth runs as one ``lax.scan`` — keeping the lowered HLO compact
(one period body) regardless of depth, which matters both for compile
time and for the roofline trip-count extrapolation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import dense_init, rms_norm


# ---------------------------------------------------------------------------
# Per-period parameter init
# ---------------------------------------------------------------------------

def init_layer_params(cfg, kind: str, key, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "ssm":
        return {
            "pre_norm": jnp.zeros((d,), dtype),
            "ssm": ssm_mod.init_ssm_params(cfg, k1, dtype),
        }
    p: Dict[str, Any] = {
        "pre_norm": jnp.zeros((d,), dtype),
        "attn": attn_mod.init_attn_params(cfg, k1, dtype),
        "pre_mlp_norm": jnp.zeros((d,), dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe_params(cfg, k2, dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp_params(cfg, k2, dtype)
    if cfg.post_norm:  # gemma2 sandwich norms
        p["post_attn_norm"] = jnp.zeros((d,), dtype)
        p["post_mlp_norm"] = jnp.zeros((d,), dtype)
    if kind == "hybrid":
        # zamba2: per-use projection of concat(hidden, first-embed) -> D;
        # the attention/MLP weights themselves are shared (see init_shared).
        p = {"pre_norm": jnp.zeros((d,), dtype),
             "fuse_proj": dense_init(k3, (2 * d, d), dtype, fan_in=2 * d),
             "ssm": ssm_mod.init_ssm_params(cfg, k1, dtype)}
    return p


def init_period_params(cfg, key, dtype) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.layer_pattern))
    return {f"l{i}": init_layer_params(cfg, kind, keys[i], dtype)
            for i, kind in enumerate(cfg.layer_pattern)}


def init_shared_params(cfg, key, dtype) -> Optional[Dict[str, Any]]:
    """Zamba2 shared attention+MLP block (one copy reused every period)."""
    if "hybrid" not in cfg.layer_pattern:
        return None
    k1, k2 = jax.random.split(key)
    return {
        "pre_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_mod.init_attn_params(cfg, k1, dtype),
        "pre_mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        "mlp": mlp_mod.init_mlp_params(cfg, k2, dtype),
    }


def n_groups(cfg) -> int:
    period = len(cfg.layer_pattern)
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    return cfg.num_layers // period


# ---------------------------------------------------------------------------
# Forward (train / prefill): full-sequence layer application
# ---------------------------------------------------------------------------

def _attn_layer(cfg, p, x, positions, kind, policy, *, want_cache=False):
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps, plus_one=True)
    q, k, v = attn_mod.project_qkv(cfg, p["attn"], h, positions)
    out = attn_mod.attention(q, k, v, kind=("swa" if kind == "swa" else "full"),
                             cfg=cfg, policy=policy)
    out = attn_mod.out_proj(p["attn"], out, cfg)
    if cfg.post_norm:
        out = rms_norm(out, p["post_attn_norm"], cfg.norm_eps, plus_one=True)
    x = x + out
    h = rms_norm(x, p["pre_mlp_norm"], cfg.norm_eps, plus_one=True)
    aux = 0.0
    if cfg.moe is not None:
        out, aux = moe_mod.moe_mlp(cfg, p["moe"], h, policy)
    else:
        out = mlp_mod.mlp(cfg, p["mlp"], h, policy)
    if cfg.post_norm:
        out = rms_norm(out, p["post_mlp_norm"], cfg.norm_eps, plus_one=True)
    x = x + out
    cache = _constrain_cache(k, v, policy) if want_cache else None
    return x, aux, cache


def _constrain_cache(k, v, policy):
    """Pin prefill-emitted K/V to the cache layout *before* the scan
    stacks them — otherwise XLA replicates the (G,B,S,KV,hd) ys buffer
    across the model axis (observed 200+ GiB/chip on 32k prefill)."""
    if policy is None:
        return (k, v)
    spec = policy.act_kv_cache(k.shape[2])
    return (policy.constrain(k, spec), policy.constrain(v, spec))


def _ssm_layer(cfg, p, x, policy, *, want_state=False):
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps, plus_one=True)
    if want_state:
        out, st = ssm_mod.ssm_mixer(cfg, p["ssm"], h, policy,
                                    want_state=True)
        return x + out, st
    return x + ssm_mod.ssm_mixer(cfg, p["ssm"], h, policy), None


def _shared_block(cfg, shared, p, x, x0, positions, policy, *,
                  want_cache=False):
    """Zamba2 hybrid slot: shared attn+MLP on concat(x, x0), then own ssm."""
    fused = jnp.einsum("bsd,dk->bsk",
                       jnp.concatenate([x, x0], axis=-1), p["fuse_proj"])
    h = rms_norm(fused, shared["pre_norm"], cfg.norm_eps, plus_one=True)
    q, k, v = attn_mod.project_qkv(cfg, shared["attn"], h, positions)
    out = attn_mod.attention(q, k, v, kind="full", cfg=cfg, policy=policy)
    out = attn_mod.out_proj(shared["attn"], out, cfg)
    x = x + out
    h = rms_norm(x, shared["pre_mlp_norm"], cfg.norm_eps, plus_one=True)
    x = x + mlp_mod.mlp(cfg, shared["mlp"], h, policy)
    x, st = _ssm_layer(cfg, p, x, policy, want_state=want_cache)
    cache = _constrain_cache(k, v, policy) if want_cache else None
    return x, cache, st


def period_forward(cfg, pparams, x, x0, positions, policy, shared=None, *,
                   want_cache: bool = False):
    """Apply one pattern period. Returns (x, aux, caches, ssm_states)."""
    aux_total = 0.0
    caches, states = {}, {}
    for i, kind in enumerate(cfg.layer_pattern):
        p = pparams[f"l{i}"]
        key = f"l{i}"
        if kind == "ssm":
            x, st = _ssm_layer(cfg, p, x, policy, want_state=want_cache)
            if want_cache:
                states[key] = st
        elif kind == "hybrid":
            x, cache, st = _shared_block(cfg, shared, p, x, x0, positions,
                                         policy, want_cache=want_cache)
            if want_cache:
                caches[key] = cache
                states[key] = st
        else:
            x, aux, cache = _attn_layer(cfg, p, x, positions, kind, policy,
                                        want_cache=want_cache)
            aux_total = aux_total + aux
            if want_cache:
                caches[key] = cache
        if policy is not None:
            x = policy.constrain(x, policy.act_hidden())
    return x, aux_total, caches, states


def stack_forward(cfg, blocks, x, positions, policy, shared=None, *,
                  remat: bool = True, remat_policy=None):
    """Scan the stacked periods over depth. blocks: pytree with leading
    n_groups dim. Returns (x, total_aux)."""
    x0 = x

    def body(carry, gparams):
        h, aux = carry
        h2, aux2, _, _ = period_forward(cfg, gparams, h, x0, positions,
                                        policy, shared)
        return (h2, aux + aux2), None

    if remat:
        body = jax.checkpoint(body, policy=remat_policy)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux


def stack_prefill(cfg, blocks, x, positions, policy, shared=None):
    """Full-sequence pass that also emits per-layer caches + ssm states."""
    x0 = x

    def body(h, gparams):
        h2, _, caches, states = period_forward(
            cfg, gparams, h, x0, positions, policy, shared, want_cache=True)
        return h2, (caches, states)

    x, (caches, states) = jax.lax.scan(body, x, blocks)
    return x, caches, states


# ---------------------------------------------------------------------------
# Decode: one token through the stack with per-layer caches
# ---------------------------------------------------------------------------

def period_decode(cfg, pparams, x, x0, caches, ssm_states, cur_pos, policy,
                  shared=None):
    """One-token step through a period.

    caches: dict f"l{i}" -> cache pytree for attention slots.
    ssm_states: dict f"l{i}" -> SSMState for ssm/hybrid slots.
    """
    from repro.serve.kvcache import (cache_positions, read_kv,
                                     update_any_cache as update_cache)

    new_caches, new_states = {}, {}
    for i, kind in enumerate(cfg.layer_pattern):
        p = pparams[f"l{i}"]
        key = f"l{i}"
        if kind == "ssm":
            h = rms_norm(x, p["pre_norm"], cfg.norm_eps, plus_one=True)
            out, new_states[key] = ssm_mod.ssm_decode_step(
                cfg, p["ssm"], h, ssm_states[key], policy)
            x = x + out
            continue
        if kind == "hybrid":
            fused = jnp.einsum(
                "bsd,dk->bsk", jnp.concatenate([x, x0], axis=-1),
                p["fuse_proj"])
            h = rms_norm(fused, shared["pre_norm"], cfg.norm_eps,
                         plus_one=True)
            q, k, v = attn_mod.project_qkv(cfg, shared["attn"], h,
                                           positions_of(cur_pos, x))
            cache = update_cache(caches[key], k, v, cur_pos)
            new_caches[key] = cache
            k_r, v_r = read_kv(cache, k.dtype)
            out = attn_mod.decode_attention(
                q, k_r, v_r, cache_positions(cache), cur_pos,
                cfg=cfg, policy=policy)
            out = attn_mod.out_proj(shared["attn"], out, cfg)
            x = x + out
            h = rms_norm(x, shared["pre_mlp_norm"], cfg.norm_eps,
                         plus_one=True)
            x = x + mlp_mod.mlp(cfg, shared["mlp"], h, policy)
            h = rms_norm(x, p["pre_norm"], cfg.norm_eps, plus_one=True)
            out, new_states[key] = ssm_mod.ssm_decode_step(
                cfg, p["ssm"], h, ssm_states[key], policy)
            x = x + out
            continue
        # attention slot (full or swa)
        h = rms_norm(x, p["pre_norm"], cfg.norm_eps, plus_one=True)
        q, k, v = attn_mod.project_qkv(cfg, p["attn"], h,
                                       positions_of(cur_pos, x))
        cache = update_cache(caches[key], k, v, cur_pos)
        new_caches[key] = cache
        k_r, v_r = read_kv(cache, k.dtype)
        out = attn_mod.decode_attention(
            q, k_r, v_r, cache_positions(cache), cur_pos, cfg=cfg,
            window=cfg.window if kind == "swa" else None, policy=policy)
        out = attn_mod.out_proj(p["attn"], out, cfg)
        if cfg.post_norm:
            out = rms_norm(out, p["post_attn_norm"], cfg.norm_eps,
                           plus_one=True)
        x = x + out
        h = rms_norm(x, p["pre_mlp_norm"], cfg.norm_eps, plus_one=True)
        if cfg.moe is not None:
            out, _ = moe_mod.moe_mlp(cfg, p["moe"], h, policy)
        else:
            out = mlp_mod.mlp(cfg, p["mlp"], h, policy)
        if cfg.post_norm:
            out = rms_norm(out, p["post_mlp_norm"], cfg.norm_eps,
                           plus_one=True)
        x = x + out
    return x, new_caches, new_states


def positions_of(cur_pos, x):
    """Rope/mask positions for a one-token step; cur_pos scalar or (B,)."""
    cur_pos = jnp.asarray(cur_pos, jnp.int32)
    if cur_pos.ndim == 0:
        return jnp.full((x.shape[0], x.shape[1]), cur_pos, jnp.int32)
    return jnp.broadcast_to(cur_pos[:, None],
                            (x.shape[0], x.shape[1])).astype(jnp.int32)


def stack_decode(cfg, blocks, x, caches, ssm_states, cur_pos, policy,
                 shared=None):
    """Scan one token through all periods, threading stacked caches."""
    x0 = x

    def body(h, xs):
        gparams, gcaches, gstates = xs
        h2, nc, ns = period_decode(cfg, gparams, h, x0, gcaches, gstates,
                                   cur_pos, policy, shared)
        return h2, (nc, ns)

    x, (new_caches, new_states) = jax.lax.scan(
        body, x, (blocks, caches, ssm_states))
    return x, new_caches, new_states
