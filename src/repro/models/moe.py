"""Capacity-based top-k mixture of experts (Switch/GShard style dispatch).

TPU-native formulation with **grouped dispatch**: tokens are split into
G groups aligned with the data-parallel shards, and the sort → capacity →
scatter pipeline runs *per group* (vmapped). Every index operation then
carries the sharded group dim, so XLA SPMD keeps dispatch fully sharded —
the naive global-sort formulation forces replicated (T·K, D) gathers
(observed 200+ GiB/chip temp on 32k prefill before this change).

Within a group: tokens sort by assigned expert, land in a static
(E, C, D) capacity buffer (C = ceil(T_g·k/E·capacity_factor)), the expert
MLPs run as one batched einsum over the expert dim (MXU-friendly), and
results gather back with the router combine weights. Overflow drops
(standard capacity semantics); the FLOPs over-provision is exactly the
capacity factor, visible in §Roofline's useful_ratio.

Sharding modes:
  * "tp" (grok-1, E=8):  buffers P(batch, None, None, None); expert
    weights (E, D, F) with F on the model axis.
  * "ep" (dbrx, E=16):   buffers P(batch, ep, None, None); expert weights
    one-per-model-shard — the scatter into the ep-sharded buffer is the
    EP all-to-all, emitted by SPMD from the sharding constraint.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import activation, dense_init


def init_moe_params(cfg, key, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype, fan_in=d),
        "moe_gate": dense_init(ks[1], (e, d, f), dtype, fan_in=d),
        "moe_up": dense_init(ks[2], (e, d, f), dtype, fan_in=d),
        "moe_down": dense_init(ks[3], (e, f, d), dtype, fan_in=f),
    }


def capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.moe.top_k * cfg.moe.capacity_factor
            // cfg.moe.num_experts)
    return max(c + (-c) % 128, 128)      # round up to an MXU-friendly 128


def _group_dispatch(xt, expert_ids, gate_vals, C: int, E: int):
    """Per-group dispatch (runs under vmap over the group dim).

    xt (T, D) · expert_ids (T, K) · gate_vals (T, K) →
    buf (E, C, D), plus gather metadata for the combine."""
    T, K = expert_ids.shape
    flat_expert = expert_ids.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    same = jax.nn.one_hot(sorted_expert, E, dtype=jnp.int32)   # (TK, E)
    pos_in_expert = (jnp.cumsum(same, axis=0) - same)[
        jnp.arange(T * K), sorted_expert]
    keep = pos_in_expert < C

    scatter_e = jnp.where(keep, sorted_expert, E - 1)
    scatter_c = jnp.where(keep, pos_in_expert, C - 1)
    contrib = jnp.where(keep[:, None], xt[sorted_token], 0)
    buf = jnp.zeros((E, C, xt.shape[-1]), xt.dtype) \
             .at[scatter_e, scatter_c].add(contrib.astype(xt.dtype))
    return buf, (scatter_e, scatter_c, sorted_token, sorted_gate, keep)


def _group_combine(out_buf, meta, T: int, D: int):
    scatter_e, scatter_c, sorted_token, sorted_gate, keep = meta
    gathered = out_buf[scatter_e, scatter_c]                   # (TK, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered.astype(jnp.float32) * sorted_gate[:, None]
    return jnp.zeros((T, D), jnp.float32).at[sorted_token].add(weighted)


def moe_mlp(cfg, p, x, policy=None):
    """x (B,S,D) -> (B,S,D), plus aux load-balancing loss."""
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    T = B * S
    G = policy.dp_size if policy is not None else 1
    if T % G:
        G = 1
    Tg = T // G
    C = capacity(Tg, cfg)
    act = activation(cfg.act)

    xt = x.reshape(G, Tg, D)
    if policy is not None:
        xt = policy.constrain(xt, P(policy.batch(), None, None))
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,Tg,E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)            # (G,Tg,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch eq. 4), over all tokens
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[..., 0], E, dtype=jnp.float32),
        axis=(0, 1))
    router_mean = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(density * router_mean)

    buf, meta = jax.vmap(
        lambda a, b, c: _group_dispatch(a, b, c, C, E))(
        xt, expert_ids, gate_vals)                             # (G,E,C,D)

    buf_spec = (P(policy.batch(), policy.ep_axis, None, None)
                if policy is not None else None)
    if policy is not None:
        buf = policy.constrain(buf, buf_spec)

    gate = jnp.einsum("gecd,edf->gecf", buf, p["moe_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, p["moe_up"])
    h = act(gate) * up
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["moe_down"])
    if policy is not None:
        out_buf = policy.constrain(out_buf, buf_spec)

    out = jax.vmap(lambda ob, m: _group_combine(ob, m, Tg, D))(
        out_buf, meta)                                         # (G,Tg,D)
    if policy is not None:
        out = policy.constrain(out, P(policy.batch(), None, None))
    return out.reshape(B, S, D).astype(x.dtype), aux_loss
