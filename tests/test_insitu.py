"""In-situ chain infrastructure: endpoint registry/config, both execution
modes, marshaling accounting, and the endpoint library."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.insitu.adaptors import RadiatingSourceAdaptor, radiating_field
from repro.core.insitu.bridge import BridgeData, GridMeta
from repro.core.insitu.chain import InSituChain
from repro.core.insitu.config import ENDPOINTS, build_chain, register_endpoint
from repro.core.insitu.endpoint import Endpoint
from repro.core.insitu.endpoints.spectral_monitor import SpectralMonitorEndpoint
from repro.core.insitu.endpoints.stats import StatsEndpoint


def paper_chain_cfg(keep=0.1, out_dir="/tmp/insitu_test_pytest"):
    # keep must exceed the source's ring frequency (period 20 px ⇒
    # N/20 cycles ⇒ keep > 0.05); 0.1 keeps the signal, drops the noise.
    return {
        "mode": "insitu",
        "chain": [
            {"endpoint": "stats", "array": "field"},
            {"endpoint": "fft", "array": "field", "direction": "forward",
             "local": True},
            {"endpoint": "spectrum", "array": "field"},
            {"endpoint": "bandpass", "array": "field", "keep_frac": keep},
            {"endpoint": "fft", "array": "field", "direction": "backward",
             "local": True},
            {"endpoint": "writer", "array": "field", "out_dir": out_dir},
        ],
    }


def test_paper_workflow_denoises(tmp_path):
    src = RadiatingSourceAdaptor(dims=(128, 128))
    data = src.produce(0)
    clean = np.asarray(data.arrays["clean_reference"])
    noisy = np.asarray(data.arrays["field"])
    chain = build_chain(paper_chain_cfg(out_dir=str(tmp_path)), None,
                        data.grid)
    out = chain.execute(data)
    den = np.asarray(out.arrays["field"])
    assert np.mean((den - clean) ** 2) < 0.5 * np.mean((noisy - clean) ** 2)
    # diagnostics flowed through
    assert float(out.arrays["insitu_total_energy"]) > 0
    assert out.arrays["insitu_spectrum_e"].shape == (32,)
    files = chain.finalize()["writer"]["files"]
    assert len(files) == 1


def test_roundtrip_identity_without_filter():
    src = RadiatingSourceAdaptor(dims=(64, 64))
    data = src.produce(0)
    chain = build_chain({"chain": [
        {"endpoint": "fft", "array": "field", "direction": "forward",
         "local": True},
        {"endpoint": "fft", "array": "field", "direction": "backward",
         "local": True},
    ]}, None, data.grid)
    out = chain.execute(data)
    np.testing.assert_allclose(np.asarray(out.arrays["field"]),
                               np.asarray(data.arrays["field"]), atol=1e-4)


def test_intransit_mode_matches_insitu(tmp_path):
    src = RadiatingSourceAdaptor(dims=(64, 64))
    data = src.produce(0)
    cfg = paper_chain_cfg(out_dir=str(tmp_path))
    a = build_chain({**cfg, "mode": "insitu"}, None, data.grid)
    b = build_chain({**cfg, "mode": "intransit"}, None, data.grid)
    out_a = a.execute(data)
    out_b = b.execute(data)
    np.testing.assert_allclose(np.asarray(out_a.arrays["field"]),
                               np.asarray(out_b.arrays["field"]),
                               atol=1e-5)
    assert a.marshaling_report()["mode"] == "insitu"
    assert "timings_s" in b.marshaling_report()


def test_bandpass_kernel_vs_jnp_parity():
    src = RadiatingSourceAdaptor(dims=(64, 64))
    data = src.produce(1)
    mk = lambda use: build_chain({"chain": [
        {"endpoint": "fft", "array": "field", "direction": "forward",
         "local": True},
        {"endpoint": "bandpass", "array": "field", "keep_frac": 0.1,
         "use_kernel": use},
    ]}, None, data.grid)
    a = mk(True).execute(data)
    b = mk(False).execute(data)
    np.testing.assert_allclose(np.asarray(a.arrays["field"][0]),
                               np.asarray(b.arrays["field"][0]), atol=1e-5)
    np.testing.assert_allclose(float(a.arrays["insitu_kept_energy"]),
                               float(b.arrays["insitu_kept_energy"]),
                               rtol=1e-5)


def test_unknown_endpoint_rejected():
    with pytest.raises(KeyError):
        build_chain({"chain": [{"endpoint": "nope"}]})


def test_register_custom_endpoint():
    class Doubler(Endpoint):
        name = "doubler"

        def execute(self, data):
            arrays = dict(data.arrays)
            arrays["field"] = arrays["field"] * 2
            return data.replace(arrays=arrays)

    register_endpoint("doubler", Doubler)
    try:
        chain = build_chain({"chain": [{"endpoint": "doubler"}]})
        d = BridgeData(arrays={"field": jnp.ones((4,))})
        out = chain.execute(d)
        np.testing.assert_allclose(np.asarray(out.arrays["field"]), 2.0)
    finally:
        ENDPOINTS.pop("doubler", None)


def test_spectral_monitor_payload():
    grads = {"layer": {"w": jnp.ones((32, 128)),
                       "b": jnp.ones((4,))}}           # b filtered out
    ep = SpectralMonitorEndpoint(source="grads", nbins=8)
    out = ep.execute(BridgeData(arrays={"grads": grads}))
    spec = out.arrays["insitu_grad_spectra"]
    assert spec.shape[-1] == 8
    np.testing.assert_allclose(np.asarray(jnp.sum(spec, -1)), 1.0,
                               atol=1e-5)
    # constant rows => pure DC => zero high-frequency fraction
    assert float(out.arrays["insitu_highfreq_frac"]) < 1e-6


def test_radiating_field_noise_fraction():
    noisy, clean = radiating_field((64, 64), noise_frac=0.5, seed=0)
    frac = np.mean(noisy != clean)
    assert 0.4 < frac < 0.6
