"""M→N in-transit bridge — distinct producer and consumer meshes.

The paper's future-work deployment (§2.1, "in-transit") separates the
M processes producing data from the N processes analyzing it. The
staged chain mode already reshards *within* one mesh; this module is
the cross-mesh hop: a ``TransitBridge`` takes each field of a
``BridgeData`` sharded over a **producer** mesh and delivers it
sharded over a disjoint **consumer** mesh, where the FFT chain (or any
consumer-side computation) runs without ever touching producer
devices. ``launch/mesh.make_transit_meshes`` builds the two meshes;
``tools/launch_multihost.py --demo transit`` runs the whole topology
end to end on a real multi-process cluster.

Two transports, picked by ``via`` (default ``"auto"``):

* ``device_put`` — direct resharding. Valid only when this process
  addresses every device of both meshes (the single-process case:
  placeholder devices, or one host's GPUs split in two). Zero host
  round-trip; XLA moves exactly the bytes that change owners.
* ``host`` — the portable path for real multi-process clusters, where
  neither side can even *construct* arrays on the other's devices.
  Producer participants lower their addressable shards to host memory;
  one ``process_allgather`` moves (buffer, ownership-mask) pairs
  across the cluster; every process then reconstructs the global field
  by taking, element-wise, the contribution of the lowest-ranked
  process whose mask covers it — **bit-identical** by construction,
  with replicated regions deduplicated deterministically; consumer
  participants finally re-shard the reconstruction onto the consumer
  mesh from their own addressable slices. Non-consumer processes get
  ``None`` for the delivered arrays (they hold no piece of them).

The multi-process call contract mirrors every other collective in the
repo: ALL processes call ``send`` per field, producer participants
passing the producer-mesh ``jax.Array``s, everyone else passing
same-shaped placeholders (e.g. ``np.zeros``; only ``shape``/``dtype``
are read). ``report()`` accounts fields, per-array bytes moved, wall
seconds, and which transport ran — the in-transit analogue of the
chain's reshard accounting.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.insitu.bridge import BridgeData

VIAS = ("auto", "device_put", "host")


def _mesh_addressable(mesh) -> bool:
    me = jax.process_index()
    return all(d.process_index == me for d in mesh.devices.flat)


def _participates(mesh) -> bool:
    me = jax.process_index()
    return any(d.process_index == me for d in mesh.devices.flat)


class TransitBridge:
    """Move fields from a producer mesh onto a disjoint consumer mesh.

    ``spec_map`` overrides the consumer-side ``PartitionSpec`` per
    array name; ``default_spec`` covers the rest (default: shard the
    leading axis over the consumer mesh's first axis when divisible,
    else fully replicate — small monitor products replicate, big
    fields split). Meshes must be device-disjoint: sharing devices
    would make "in transit" a no-op and the accounting a lie.
    """

    def __init__(self, producer_mesh, consumer_mesh, *,
                 spec_map: Optional[Dict[str, P]] = None,
                 default_spec: Optional[P] = None, via: str = "auto"):
        if via not in VIAS:
            raise ValueError(f"via must be one of {VIAS}, got {via!r}")
        overlap = ({d.id for d in producer_mesh.devices.flat}
                   & {d.id for d in consumer_mesh.devices.flat})
        if overlap:
            raise ValueError(
                f"producer and consumer meshes share devices {sorted(overlap)}"
                f" — transit requires disjoint meshes")
        self.producer_mesh = producer_mesh
        self.consumer_mesh = consumer_mesh
        self.spec_map = dict(spec_map or {})
        self.default_spec = default_spec
        if via == "auto":
            via = ("device_put"
                   if (_mesh_addressable(producer_mesh)
                       and _mesh_addressable(consumer_mesh)) else "host")
        self.via = via
        self._fields = 0
        self._bytes = 0
        self._wall_s = 0.0
        self._per_array: Dict[str, int] = {}

    # -- participation ------------------------------------------------------
    def is_producer(self) -> bool:
        """True when this process owns producer-mesh devices."""
        return _participates(self.producer_mesh)

    def is_consumer(self) -> bool:
        """True when this process owns consumer-mesh devices — i.e.
        whether ``send``'s outputs are usable here."""
        return _participates(self.consumer_mesh)

    # -- spec resolution ----------------------------------------------------
    def _consumer_sharding(self, name: str, shape) -> NamedSharding:
        spec = self.spec_map.get(name, self.default_spec)
        if spec is None:
            ax0 = self.consumer_mesh.axis_names[0]
            n0 = self.consumer_mesh.shape[ax0]
            spec = P(ax0) if shape and shape[0] % n0 == 0 else P()
        return NamedSharding(self.consumer_mesh, spec)

    # -- transports ---------------------------------------------------------
    def _move_device_put(self, name: str, x):
        return jax.device_put(x, self._consumer_sharding(name, x.shape))

    def _move_host(self, name: str, x):
        """The allgather hop (see module docstring). ``x`` is a
        producer-mesh array on producer participants and a shape/dtype
        placeholder everywhere else."""
        from jax.experimental.multihost_utils import process_allgather

        shape, dtype = tuple(x.shape), np.dtype(x.dtype)
        buf = np.zeros(shape, dtype)
        mask = np.zeros(shape, np.uint8)
        shards = getattr(x, "addressable_shards", None)
        if shards is not None and isinstance(x, jax.Array):
            for s in shards:
                buf[s.index] = np.asarray(s.data)
                mask[s.index] = 1
        gbuf = np.asarray(process_allgather(buf))
        gmask = np.asarray(process_allgather(mask))
        if gbuf.shape == shape:          # single process: no leading axis
            gbuf, gmask = gbuf[None], gmask[None]
        full = np.zeros(shape, dtype)
        filled = np.zeros(shape, bool)
        for p in range(gbuf.shape[0]):
            take = gmask[p].astype(bool) & ~filled
            full[take] = gbuf[p][take]
            filled |= take
        if not filled.all():
            raise ValueError(
                f"transit array {name!r}: no process contributed "
                f"{int((~filled).sum())} of {filled.size} elements — was "
                f"send() called with the producer-mesh array on every "
                f"producer participant?")
        if not self.is_consumer():
            return None
        sh = self._consumer_sharding(name, shape)
        local = [jax.device_put(full[idx], d) for d, idx
                 in sh.addressable_devices_indices_map(shape).items()]
        return jax.make_array_from_single_device_arrays(shape, sh, local)

    # -- the hop ------------------------------------------------------------
    def send(self, data: BridgeData) -> BridgeData:
        """Deliver one field's arrays onto the consumer mesh.

        Returns a ``BridgeData`` with the same keys/structure whose
        leaves live on the consumer mesh (``None`` leaves on
        non-consumer processes under the ``host`` transport). Grid
        metadata, step, domain and layout tags pass through untouched —
        transit moves bytes, it does not reinterpret them."""
        t0 = time.perf_counter()
        move = (self._move_device_put if self.via == "device_put"
                else self._move_host)
        out: Dict[str, Any] = {}
        for name, v in data.arrays.items():
            moved = jax.tree.map(lambda x, n=name: move(n, x), v)
            nbytes = sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
                         for x in jax.tree.leaves(v))
            self._per_array[name] = self._per_array.get(name, 0) + nbytes
            self._bytes += nbytes
            out[name] = moved
        self._fields += 1
        self._wall_s += time.perf_counter() - t0
        return data.replace(arrays=out,
                            meta={**data.meta, "transit_via": self.via})

    # -- accounting ---------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the accounting (fields/bytes/wall) without touching
        configuration — call after warm-up so ``report()`` covers
        steady state, matching ``InSituChain.reset_stats()``."""
        self._fields = 0
        self._bytes = 0
        self._wall_s = 0.0
        self._per_array.clear()

    def report(self) -> Dict[str, Any]:
        """Transit accounting: fields/bytes/seconds moved, transport,
        and both meshes' process spans — the M→N analogue of
        ``InSituChain.marshaling_report()``'s reshard accounting."""
        def span(mesh):
            return {"shape": dict(mesh.shape),
                    "processes": sorted({d.process_index
                                         for d in mesh.devices.flat})}
        return {
            "via": self.via,
            "fields": self._fields,
            "bytes_moved": self._bytes,
            "bytes_per_array": dict(self._per_array),
            "wall_s": self._wall_s,
            "producer": span(self.producer_mesh),
            "consumer": span(self.consumer_mesh),
        }
