"""Continuous-batching engine: outputs must be identical to serial
per-request greedy decoding, with slots joining/leaving mid-flight."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve.engine import ContinuousBatcher, Request


def serial_greedy(cfg, params, prompt, max_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, state = lm.prefill(cfg, params, {"tokens": toks},
                               cache_len=len(prompt) + max_new + 2)
    out = []
    tok = jnp.argmax(logits[0, -1])
    for _ in range(max_new):
        out.append(int(tok))
        logits, state = lm.decode_step(
            cfg, params, jnp.asarray([[int(tok)]], jnp.int32), state)
        tok = jnp.argmax(logits[0, -1])
    return out


def test_engine_matches_serial_decode():
    cfg = registry.get_reduced("qwen3-4b")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=p).astype(np.int32)
               for p in (5, 7, 4, 6, 5)]
    max_new = 6

    eng = ContinuousBatcher(cfg, params, slots=2, cache_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=max_new))
    finished = eng.run()
    assert len(finished) == len(prompts)

    for i, p in enumerate(prompts):
        want = serial_greedy(cfg, params, p, max_new)
        assert finished[i].out == want, (i, finished[i].out, want)


def test_engine_slot_reuse():
    """More requests than slots: slots must be reused."""
    cfg = registry.get_reduced("qwen3-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    rng = np.random.default_rng(1)
    eng = ContinuousBatcher(cfg, params, slots=2, cache_len=32)
    n = 5
    for i in range(n):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 4)
                           .astype(np.int32), max_new=3))
    finished = eng.run()
    assert len(finished) == n
    assert all(len(r.out) == 3 for r in finished.values())
